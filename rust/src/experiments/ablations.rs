//! Ablations beyond the paper's headline evaluation — each one probes a
//! design choice DESIGN.md calls out, or implements a Future-Work item:
//!
//! * `ablation_groups`   — group-rule granularity (2 / 5 / 9 groups).
//! * `ablation_batch`    — request-level vs batch-level routing (FW #2).
//! * `ablation_weighted` — Algorithm 1 vs weighted multi-objective (FW #3).
//! * `ablation_drift`    — static profiles vs drifting fleet vs periodic
//!                         re-profiling (FW #1).
//! * `ablation_failover` — node-failure injection and fallback cost.

use anyhow::Result;

use super::serve::deployed_store;
use super::Harness;
use crate::dataset::coco;
use crate::devices::drift::DriftConfig;
use crate::gateway::{router_by_name, Gateway};
use crate::metrics::RunMetrics;
use crate::nodes::NodePool;
use crate::router::{
    GroupRules, PairKey, ProfileStore, WeightedRouter, Weights,
};
use crate::router::group::GroupRule;
use crate::util::json::Json;
use crate::workload;

fn fresh_gateway<'e>(
    h: &'e Harness,
    router: &str,
    deployed: &ProfileStore,
    delta: f64,
) -> Result<Gateway<'e>> {
    let pool = NodePool::deploy(
        &h.engine,
        &deployed.pairs(),
        &crate::devices::fleet(),
        h.cfg.seed,
    )?;
    Ok(Gateway::new(
        &h.engine,
        router_by_name(router).unwrap(),
        deployed.clone(),
        pool,
        delta,
        h.cfg.seed,
    ))
}

/// Group-rule granularity: coarser rules blunt the router's adaptivity,
/// finer rules add nothing once groups resolve the accuracy cliffs.
pub fn ablation_groups(h: &Harness) -> Result<()> {
    let n = (h.cfg.coco_images / 2).max(80);
    let ds = coco::build(n, h.cfg.seed ^ 0xAB1);
    let full = h.profiles()?;

    // regroup the store's rows under coarser/finer rules by re-keying
    // profiled groups through a mapping on representative counts.
    let rule_sets: Vec<(&str, GroupRules)> = vec![
        (
            "2 groups (0-1 | 2+)",
            GroupRules::new(vec![
                GroupRule { lo: 0, hi: 1, label: 0 },
                GroupRule { lo: 2, hi: usize::MAX, label: 1 },
            ])
            .unwrap(),
        ),
        ("5 groups (paper)", GroupRules::paper_default()),
    ];

    println!("--- ablation_groups ({n} images) ---");
    println!(
        "{:<22} {:>8} {:>12} {:>12}",
        "rules", "mAP", "energy_mWh", "latency_s"
    );
    let mut out = Vec::new();
    for (name, rules) in &rule_sets {
        // collapse profiled groups through the rule set: profiled group g
        // (representative count = g, 4 => "4+") maps to rules.group_of
        let mut rows = Vec::new();
        for r in full.rows() {
            let mut nr = r.clone();
            nr.group = rules.group_of(r.group); // representative counts
            rows.push(nr);
        }
        // aggregate duplicate (pair, group) rows by mean mAP
        let mut agg: std::collections::BTreeMap<(PairKey, usize), (f64, f64, f64, usize)> =
            std::collections::BTreeMap::new();
        for r in rows {
            let e = agg
                .entry((r.pair.clone(), r.group))
                .or_insert((0.0, 0.0, 0.0, 0));
            e.0 += r.map;
            e.1 += r.latency_s;
            e.2 += r.energy_mwh;
            e.3 += 1;
        }
        let store = ProfileStore::new(
            agg.into_iter()
                .map(|((pair, group), (m, l, e, c))| {
                    crate::router::PairProfile {
                        pair,
                        group,
                        map: m / c as f64,
                        latency_s: l / c as f64,
                        energy_mwh: e / c as f64,
                    }
                })
                .collect(),
        );
        let testbed = crate::profiling::testbed::pool(
            &crate::profiling::testbed::select(&store),
        );
        let deployed = store.restrict(&testbed);
        let mut gw = fresh_gateway(h, "Orc", &deployed, h.cfg.delta_map)?;
        // the gateway must bucket oracle counts with the SAME rules that
        // key this store's rows
        gw.set_rules(rules.clone());
        let m = workload::run_dataset(&mut gw, &ds)?;
        println!(
            "{:<22} {:>8.2} {:>12.2} {:>12.2}",
            name,
            m.map(),
            m.total_energy_mwh(),
            m.total_latency_s
        );
        out.push(Json::obj(vec![
            ("rules", Json::str(name)),
            ("map", Json::num(m.map())),
            ("energy_mwh", Json::num(m.total_energy_mwh())),
            ("latency_s", Json::num(m.total_latency_s)),
        ]));
    }
    h.save_json("ablation_groups", &Json::Arr(out))
}

/// Request-level vs batch-level routing (Future Work #2).
pub fn ablation_batch(h: &Harness) -> Result<()> {
    let n = (h.cfg.coco_images / 2).max(80);
    let ds = coco::build(n, h.cfg.seed ^ 0xAB2);
    let deployed = deployed_store(h)?;

    println!("--- ablation_batch ({n} images) ---");
    println!(
        "{:<14} {:>8} {:>12} {:>12} {:>10}",
        "mode", "mAP", "energy_mWh", "latency_s", "gw_mWh"
    );
    let mut out = Vec::new();

    // per-request baseline
    let mut gw = fresh_gateway(h, "ED", &deployed, h.cfg.delta_map)?;
    let per_req = workload::run_dataset(&mut gw, &ds)?;

    // batched: window of 8 consecutive requests, one decision per batch
    for batch in [4usize, 8] {
        let mut gw = fresh_gateway(h, "ED", &deployed, h.cfg.delta_map)?;
        let mut m = RunMetrics::new("ED-batch");
        let scenes: Vec<_> = ds.iter_scenes().collect();
        for chunk in scenes.chunks(batch) {
            let images: Vec<(Vec<f32>, usize, Vec<crate::dataset::GtBox>)> =
                chunk
                    .iter()
                    .map(|s| (s.image.clone(), s.gt.len(), s.gt.clone()))
                    .collect();
            gw.handle_batch(&images, &mut m)?;
        }
        println!(
            "{:<14} {:>8.2} {:>12.2} {:>12.2} {:>10.3}",
            format!("batch={batch}"),
            m.map(),
            m.total_energy_mwh(),
            m.total_latency_s,
            m.gateway_energy_mwh
        );
        out.push(Json::obj(vec![
            ("mode", Json::str(&format!("batch{batch}"))),
            ("map", Json::num(m.map())),
            ("energy_mwh", Json::num(m.total_energy_mwh())),
            ("latency_s", Json::num(m.total_latency_s)),
        ]));
    }
    println!(
        "{:<14} {:>8.2} {:>12.2} {:>12.2} {:>10.3}",
        "per-request",
        per_req.map(),
        per_req.total_energy_mwh(),
        per_req.total_latency_s,
        per_req.gateway_energy_mwh
    );
    out.push(Json::obj(vec![
        ("mode", Json::str("per_request")),
        ("map", Json::num(per_req.map())),
        ("energy_mwh", Json::num(per_req.total_energy_mwh())),
        ("latency_s", Json::num(per_req.total_latency_s)),
    ]));
    h.save_json("ablation_batch", &Json::Arr(out))
}

/// Algorithm 1 vs weighted scalarization (Future Work #3).
pub fn ablation_weighted(h: &Harness) -> Result<()> {
    let deployed = deployed_store(h)?;
    println!("--- ablation_weighted (per-group route choices) ---");
    let greedy = crate::router::GreedyRouter::new(h.cfg.delta_map);
    let settings = [
        ("energy-heavy", Weights { energy: 3.0, latency: 0.2, accuracy: 1.0 }),
        ("balanced", Weights { energy: 1.0, latency: 1.0, accuracy: 1.0 }),
        ("accuracy-heavy", Weights { energy: 0.3, latency: 0.2, accuracy: 3.0 }),
    ];
    let mut out = Vec::new();
    for g in deployed.groups() {
        let gchoice = greedy.route(&deployed, g);
        print!("group {g}: greedy={}", gchoice.as_ref().map(|p| p.to_string()).unwrap_or_default());
        let mut row = vec![
            ("group", Json::num(g as f64)),
            (
                "greedy",
                Json::str(&gchoice.map(|p| p.to_string()).unwrap_or_default()),
            ),
        ];
        for (name, w) in &settings {
            let c = WeightedRouter::new(*w)
                .route(&deployed, g)
                .map(|p| p.to_string())
                .unwrap_or_default();
            print!("  {name}={c}");
            row.push((*name, Json::str(&c)));
        }
        println!();
        out.push(Json::obj(row));
    }
    h.save_json("ablation_weighted", &Json::Arr(out))
}

/// Static profiles on a drifting fleet vs telemetry-corrected
/// profiles (FW #1). The correction arms run through the production
/// adaptation path (DESIGN.md §12) — the same EWMA corrector the
/// `adapt` experiment sweeps — rather than a bespoke re-profiling
/// pass, so the ablation measures exactly what serving would do.
pub fn ablation_drift(h: &Harness) -> Result<()> {
    let n = (h.cfg.coco_images / 2).max(100);
    let ds = coco::build(n, h.cfg.seed ^ 0xAB4);
    let deployed = deployed_store(h)?;
    let base = crate::adapt::AdaptConfig {
        scale: false, // closed-loop replay has no arrival process
        ..h.cfg.adapt_config()?
    };

    println!("--- ablation_drift ({n} images) ---");
    println!(
        "{:<26} {:>8} {:>12} {:>12} {:>7}",
        "fleet", "mAP", "energy_mWh", "latency_s", "corr"
    );
    let mut out = Vec::new();

    // arms: (label, drift on, adaptation config)
    let arms: [(&str, bool, Option<crate::adapt::AdaptConfig>); 4] = [
        ("static (paper)", false, None),
        ("drifting, stale profiles", true, None),
        (
            "drifting, online adapt",
            true,
            Some(crate::adapt::AdaptConfig {
                publish_every: 0,
                ..base.clone()
            }),
        ),
        (
            "drifting, periodic adapt",
            true,
            Some(crate::adapt::AdaptConfig {
                publish_every: 25,
                ..base.clone()
            }),
        ),
    ];
    let mut measured = Vec::new();
    for (name, drift, adapt) in &arms {
        let mut gw = fresh_gateway(h, "Orc", &deployed, h.cfg.delta_map)?;
        if *drift {
            gw.pool_mut()
                .enable_drift(&DriftConfig::default(), h.cfg.seed);
        }
        if let Some(a) = adapt {
            gw.enable_adapt(a);
        }
        let m = workload::run_dataset(&mut gw, &ds)?;
        // closed-loop replay has no wall clock, so the report carries
        // telemetry stats only (node-seconds need a makespan)
        let corr = gw
            .adapt_report(0.0)
            .map(|r| r.mean_correction)
            .unwrap_or(1.0);
        println!(
            "{:<26} {:>8.2} {:>12.2} {:>12.2} {:>7.3}",
            name,
            m.map(),
            m.total_energy_mwh(),
            m.total_latency_s,
            corr
        );
        out.push(Json::obj(vec![
            ("fleet", Json::str(name)),
            ("map", Json::num(m.map())),
            ("energy_mwh", Json::num(m.total_energy_mwh())),
            ("latency_s", Json::num(m.total_latency_s)),
            ("mean_correction", Json::num(corr)),
        ]));
        measured.push(m);
    }
    let excess = crate::util::stats::pct_change(
        measured[0].total_energy_mwh(),
        measured[1].total_energy_mwh(),
    );
    let recovered = crate::util::stats::pct_change(
        measured[1].total_energy_mwh(),
        measured[2].total_energy_mwh(),
    );
    println!("drift cost: {excess:+.1}% energy over the static assumption");
    println!("online adapt: {recovered:+.1}% energy vs stale profiles");
    h.save_json("ablation_drift", &Json::Arr(out))
}

/// Failure injection: kill the greedy router's favourite pair mid-run
/// and measure the fallback's cost.
pub fn ablation_failover(h: &Harness) -> Result<()> {
    let n = (h.cfg.coco_images / 2).max(100);
    let ds = coco::build(n, h.cfg.seed ^ 0xAB5);
    let deployed = deployed_store(h)?;

    // find the greedy favourite for the crowded group and kill it
    let greedy = crate::router::GreedyRouter::new(h.cfg.delta_map);
    let favourite = greedy
        .route(&deployed, 4)
        .ok_or_else(|| anyhow::anyhow!("no crowded-group route"))?;

    println!("--- ablation_failover ({n} images, killing {favourite}) ---");
    let mut out = Vec::new();
    for (name, kill) in [("healthy", false), ("favourite down", true)] {
        let mut gw = fresh_gateway(h, "Orc", &deployed, h.cfg.delta_map)?;
        if kill {
            assert!(gw.pool_mut().set_health(&favourite, false));
        }
        let m = workload::run_dataset(&mut gw, &ds)?;
        println!(
            "{:<18} mAP {:>6.2}  energy {:>8.2}  fallbacks {}",
            name,
            m.map(),
            m.total_energy_mwh(),
            gw.fallbacks
        );
        out.push(Json::obj(vec![
            ("scenario", Json::str(name)),
            ("map", Json::num(m.map())),
            ("energy_mwh", Json::num(m.total_energy_mwh())),
            ("fallbacks", Json::num(gw.fallbacks as f64)),
        ]));
    }
    h.save_json("ablation_failover", &Json::Arr(out))
}

pub fn run_all(h: &Harness) -> Result<()> {
    ablation_groups(h)?;
    ablation_batch(h)?;
    ablation_weighted(h)?;
    ablation_drift(h)?;
    ablation_failover(h)
}
