//! Static (non-serving) experiments: Fig. 2 (motivating prelim), Fig. 4
//! (count distribution), Fig. 5 (64-pair Pareto grid), Table 1 (testbed
//! selection).

use anyhow::Result;

use super::Harness;
use crate::dataset::{coco, Dataset, SceneSpec};
use crate::detection::decode_heatmap;
use crate::detection::map::{map_coco, ImageEval};
use crate::devices;
use crate::profiling::testbed;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Fig. 2: SSD-Lite vs YOLOv8n on single-object vs 4+-object images —
/// similar accuracy when sparse, ~2x mAP gap when crowded, while the
/// light model's energy stays ~50% lower.
pub fn fig2(h: &Harness) -> Result<()> {
    let n = h.cfg.profile_per_group.max(24);
    let models = ["ssd_lite", "yolov8n"];
    let device = devices::find(&devices::fleet(), "pi5").unwrap();

    let build_group = |n_objects_choices: &[usize], tag: u64| -> Dataset {
        let base = Rng::new(h.cfg.seed ^ tag);
        Dataset {
            name: format!("fig2_{tag}"),
            specs: (0..n)
                .map(|j| {
                    let mut r = base.derive(j as u64);
                    let n_objects = n_objects_choices
                        [r.below(n_objects_choices.len() as u64) as usize];
                    SceneSpec {
                        id: j,
                        seed: r.next_u64(),
                        n_objects,
                    }
                })
                .collect(),
        }
    };
    let groups = [
        ("single", build_group(&[1], 0xF2A)),
        ("4plus", build_group(&[4, 5, 6, 7, 8], 0xF2B)),
    ];

    println!("--- fig2 (prelim: accuracy & energy by scene complexity) ---");
    println!(
        "{:<10} {:<9} {:>8} {:>16}",
        "model", "group", "mAP", "energy_mWh/img"
    );
    let mut out = Vec::new();
    for model in models {
        let meta = h.engine.meta(model)?;
        let prof = device.profile(&meta);
        for (gname, ds) in &groups {
            let mut evals = Vec::with_capacity(ds.len());
            for scene in ds.iter_scenes() {
                let heat = h.engine.infer(model, &scene.image)?;
                evals.push(ImageEval {
                    dets: decode_heatmap(&heat, &meta, prof.threshold_scale),
                    gt: scene.gt.clone(),
                });
            }
            let map = map_coco(&evals, crate::dataset::NUM_CLASSES).map;
            println!(
                "{:<10} {:<9} {:>8.2} {:>16.4}",
                model, gname, map, prof.energy_mwh
            );
            out.push(Json::obj(vec![
                ("model", Json::str(model)),
                ("group", Json::str(gname)),
                ("map", Json::num(map)),
                ("energy_mwh_per_image", Json::num(prof.energy_mwh)),
            ]));
        }
    }
    h.save_json("fig2", &Json::Arr(out))
}

/// Fig. 4: object-count distribution of the synthetic COCO val set.
pub fn fig4(h: &Harness) -> Result<()> {
    let ds = coco::build(5000, h.cfg.seed ^ 0xC0C0);
    let hist = coco::count_histogram(&ds);
    println!("--- fig4 (object-count distribution, 5000 images) ---");
    let max = *hist.iter().max().unwrap() as f64;
    for (count, &images) in hist.iter().enumerate() {
        let bar = "#".repeat((40.0 * images as f64 / max) as usize);
        let label = if count == coco::MAX_COUNT {
            format!("{count}+")
        } else {
            format!("{count}")
        };
        println!("{label:>3} | {images:>4} {bar}");
    }
    h.save_json(
        "fig4",
        &Json::obj(vec![(
            "histogram",
            Json::arr_f64(
                &hist.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            ),
        )]),
    )
}

/// One fig5 scatter point: a pair's mean detection mAP and per-image
/// cost.
#[derive(Clone)]
struct Fig5Row {
    pair: crate::router::PairKey,
    map: f64,
    energy: f64,
    latency: f64,
}

/// Ascending energy with a total order: a NaN energy from a corrupt
/// profile cache sorts last instead of panicking, and energy ties
/// break by pair key so the fig5 listing (and therefore the Pareto
/// marking) is deterministic across runs.
fn sort_by_energy(rows: &mut [Fig5Row]) {
    rows.sort_by(|a, b| {
        a.energy.total_cmp(&b.energy).then_with(|| a.pair.cmp(&b.pair))
    });
}

/// Fig. 5: the 64-combination accuracy–energy grid with Pareto marking.
pub fn fig5(h: &Harness) -> Result<()> {
    let store = h.profiles()?;
    // aggregate per pair: mean mAP over groups 1..4 (group 0 is the
    // clean-image score, not a detection metric), energy per inference
    let pairs = store.pairs();
    let mut rows: Vec<Fig5Row> = pairs
        .iter()
        .map(|p| {
            let maps: Vec<f64> = (1..=4)
                .filter_map(|g| store.lookup(p, g).map(|r| r.map))
                .collect();
            let any = store.lookup(p, 1).unwrap();
            Fig5Row {
                pair: p.clone(),
                map: maps.iter().sum::<f64>() / maps.len() as f64,
                energy: any.energy_mwh,
                latency: any.latency_s,
            }
        })
        .collect();
    sort_by_energy(&mut rows);
    // Pareto front: minimal energy, maximal mAP
    let mut best_map = f64::NEG_INFINITY;
    let mut pareto = vec![false; rows.len()];
    for (i, r) in rows.iter().enumerate() {
        if r.map > best_map {
            best_map = r.map;
            pareto[i] = true;
        }
    }
    println!("--- fig5 (64 model-device pairs: energy vs mAP) ---");
    println!(
        "{:<30} {:>10} {:>10} {:>10}  pareto",
        "pair", "mAP", "mWh/img", "lat_ms"
    );
    let mut out = Vec::new();
    for (i, r) in rows.iter().enumerate() {
        println!(
            "{:<30} {:>10.2} {:>10.4} {:>10.2}  {}",
            r.pair.to_string(),
            r.map,
            r.energy,
            1000.0 * r.latency,
            if pareto[i] { "*" } else { "" }
        );
        out.push(Json::obj(vec![
            ("model", Json::str(&r.pair.model)),
            ("device", Json::str(&r.pair.device)),
            ("map", Json::num(r.map)),
            ("energy_mwh", Json::num(r.energy)),
            ("latency_s", Json::num(r.latency)),
            ("pareto", Json::Bool(pareto[i])),
        ]));
    }
    println!(
        "pareto-front pairs: {}",
        pareto.iter().filter(|&&x| x).count()
    );
    // the paper's scatter, in ASCII: energy (x, log10 mWh) vs mAP (y)
    let front: Vec<(f64, f64)> = rows
        .iter()
        .enumerate()
        .filter(|(i, _)| pareto[*i])
        .map(|(_, r)| (r.energy.log10(), r.map))
        .collect();
    let rest: Vec<(f64, f64)> = rows
        .iter()
        .enumerate()
        .filter(|(i, _)| !pareto[*i])
        .map(|(_, r)| (r.energy.log10(), r.map))
        .collect();
    println!(
        "{}",
        crate::util::chart::line_chart(
            "fig5: log10(energy mWh/img) vs mAP",
            &[("pareto front", front), ("dominated", rest)],
            64,
            18,
        )
    );
    h.save_json("fig5", &Json::Arr(out))
}

/// Table 1: per-metric champions (the deployed testbed).
pub fn table1(h: &Harness) -> Result<()> {
    let store = h.profiles()?;
    let rows = testbed::select(&store);
    println!("--- table1 (testbed selection) ---");
    println!("{:<12} {:<30} {:>12}", "metric", "pair", "value");
    let mut out = Vec::new();
    for r in &rows {
        let device =
            devices::find(&devices::fleet(), &r.pair.device).unwrap();
        let meta = h.engine.meta(&r.pair.model)?;
        let fw = device.profile(&meta).framework;
        println!(
            "{:<12} {:<30} {:>12.4}   ({})",
            r.metric,
            r.pair.to_string(),
            r.value,
            fw.label()
        );
        out.push(Json::obj(vec![
            ("metric", Json::str(&r.metric)),
            ("model", Json::str(&r.pair.model)),
            ("device", Json::str(&r.pair.device)),
            ("framework", Json::str(fw.label())),
            ("value", Json::num(r.value)),
        ]));
    }
    println!(
        "deployed pool: {} unique pairs",
        testbed::pool(&rows).len()
    );
    h.save_json("table1", &Json::Arr(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::PairKey;

    fn row(model: &str, energy: f64) -> Fig5Row {
        Fig5Row {
            pair: PairKey::new(model, "d"),
            map: 50.0,
            energy,
            latency: 0.01,
        }
    }

    #[test]
    fn nan_energy_sorts_last_and_ties_break_by_pair_key() {
        // regression: `sort_by(partial_cmp().unwrap())` panicked when a
        // hand-edited profile cache carried a NaN energy
        let mut rows = vec![
            row("b", 2.0),
            row("poisoned", f64::NAN),
            row("c", 1.0),
            row("a", 2.0),
        ];
        sort_by_energy(&mut rows);
        let order: Vec<&str> =
            rows.iter().map(|r| r.pair.model.as_str()).collect();
        assert_eq!(order, ["c", "a", "b", "poisoned"]);
    }
}
