//! Scene generation throughput: dataset rendering runs once per request
//! in every experiment, so it must stay cheap relative to inference.

use ecore::dataset::{scene, video, SceneSpec};
use ecore::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("dataset");
    for n in [0usize, 1, 4, 12] {
        let name = format!("render_{n}_objects");
        let mut seed = 0u64;
        b.run(&name, || {
            seed += 1;
            black_box(scene::render_spec(&SceneSpec {
                id: 0,
                seed,
                n_objects: n,
            }))
        });
    }
    b.run("video_30_frames", || {
        black_box(video::build_frames(30, 5))
    });
    b.finish();
}
