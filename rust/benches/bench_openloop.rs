//! Open-loop simulator throughput: full discrete-event runs (arrival
//! sampling → route with occupancy check → PJRT service → completion
//! bookkeeping) over the real deployed testbed, at a low rate (no
//! queueing, the closed-loop-equivalent regime) and at saturation
//! (deep queues, fallback re-routes). The spread between the two is the
//! pure event-queue + queueing-layer overhead.

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::nodes::NodePool;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::{
    run_frames, ArrivalProcess, OpenLoopConfig,
};

fn main() {
    let cfg = ExperimentConfig {
        profile_per_group: 12,
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let mut b = Bench::new("openloop");
    for (router, rate, cap) in [
        ("LE", 2.0, 8),
        ("LE", 500.0, 64),
        ("ED", 500.0, 64),
        ("HMG", 500.0, 4),
    ] {
        let name = format!("{router}_rate{rate}_cap{cap}");
        b.run(&name, || {
            let pool = NodePool::deploy(
                &h.engine,
                &deployed.pairs(),
                &ecore::devices::fleet(),
                1,
            )
            .unwrap();
            let mut gw = Gateway::new(
                &h.engine,
                router_by_name(router).unwrap(),
                deployed.clone(),
                pool,
                5.0,
                1,
            );
            let report = run_frames(
                &mut gw,
                &frames,
                &gts,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: rate },
                    queue_capacity: cap,
                    seed: 3,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap();
            black_box(report.metrics.requests)
        });
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish();
}
