//! Churn simulator throughput: full open-loop discrete-event runs over
//! the real deployed testbed with the lifecycle layer active — seeded
//! crash/rejoin injection, per-probe membership updates, stale-view
//! dispatch failures, and the resilience policies. The spread against
//! `bench_openloop`'s saturated configuration is the pure cost of the
//! churn machinery (failure timeline, probe events, copy accounting);
//! the policy rows show what retrying and hedging cost on top.

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};
use ecore::nodes::NodePool;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::{
    run_frames, ArrivalProcess, OpenLoopConfig,
};

fn main() {
    let cfg = ExperimentConfig {
        profile_per_group: 12,
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let mut b = Bench::new("churn");
    for (name, churn) in [
        ("no_churn", None),
        (
            "retry_avail80",
            Some(ChurnConfig {
                mtbf_s: 0.8,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                policy: ResiliencePolicy::Retry { budget: 4 },
                retry_backoff_s: 0.05,
                horizon_slack_s: 2.0,
                ..Default::default()
            }),
        ),
        (
            "hedge_avail80",
            Some(ChurnConfig {
                mtbf_s: 0.8,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                policy: ResiliencePolicy::Hedge,
                horizon_slack_s: 2.0,
                ..Default::default()
            }),
        ),
    ] {
        b.run(name, || {
            let pool = NodePool::deploy(
                &h.engine,
                &deployed.pairs(),
                &ecore::devices::fleet(),
                1,
            )
            .unwrap();
            let mut gw = Gateway::new(
                &h.engine,
                router_by_name("ED").unwrap(),
                deployed.clone(),
                pool,
                5.0,
                1,
            );
            let report = run_frames(
                &mut gw,
                &frames,
                &gts,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 500.0 },
                    queue_capacity: 8,
                    seed: 3,
                    churn: churn.clone(),
                },
            )
            .unwrap();
            black_box(report.metrics.requests + report.lost())
        });
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish();
}
