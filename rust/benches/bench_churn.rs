//! Churn simulator throughput: full open-loop discrete-event runs over
//! the real deployed testbed with the lifecycle layer active — seeded
//! crash/rejoin injection, per-probe membership updates, stale-view
//! dispatch failures, and the resilience policies. The spread against
//! `bench_openloop`'s saturated configuration is the pure cost of the
//! churn machinery (failure timeline, probe events, copy accounting);
//! the policy rows show what retrying and hedging cost on top.

use std::time::Instant;

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};
use ecore::nodes::NodePool;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::{
    run_frames, ArrivalProcess, OpenLoopConfig,
};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig {
        profile_per_group: if quick { 6 } else { 12 },
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let mut b = Bench::new("churn");
    let mut extras_owned: Vec<(String, f64)> = Vec::new();
    for (name, churn) in [
        ("no_churn", None),
        (
            "retry_avail80",
            Some(ChurnConfig {
                mtbf_s: 0.8,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                policy: ResiliencePolicy::Retry { budget: 4 },
                retry_backoff_s: 0.05,
                horizon_slack_s: 2.0,
                ..Default::default()
            }),
        ),
        (
            "hedge_avail80",
            Some(ChurnConfig {
                mtbf_s: 0.8,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                policy: ResiliencePolicy::Hedge,
                horizon_slack_s: 2.0,
                ..Default::default()
            }),
        ),
    ] {
        let run_once = || {
            let pool = NodePool::deploy(
                &h.engine,
                &deployed.pairs(),
                &ecore::devices::fleet(),
                1,
            )
            .unwrap();
            let mut gw = Gateway::new(
                &h.engine,
                router_by_name("ED").unwrap(),
                deployed.clone(),
                pool,
                5.0,
                1,
            );
            run_frames(
                &mut gw,
                &frames,
                &gts,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 500.0 },
                    queue_capacity: 8,
                    seed: 3,
                    churn: churn.clone(),
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
        };
        // warm-up + event census (deterministic per config/seed)
        let t0 = Instant::now();
        let report = run_once();
        let cold_wall = t0.elapsed().as_secs_f64();
        let events = report.offered + report.metrics.requests;
        println!(
            "{:<16} {:>10.0} events/sec cold ({} events)",
            name,
            events as f64 / cold_wall.max(1e-9),
            events
        );
        b.run(name, || {
            let report = run_once();
            black_box(report.metrics.requests + report.lost())
        });
        // headline events/sec from the MEASURED MEDIAN run time (the
        // cold run above is warm-up, not the tracked number)
        let runs_per_sec = b
            .results()
            .last()
            .expect("case just measured")
            .throughput_per_sec();
        extras_owned.push((
            format!("events_per_sec_{name}"),
            events as f64 * runs_per_sec,
        ));
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish_json(&extras_owned);
}
