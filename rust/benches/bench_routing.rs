//! Router decision latency per strategy — the gateway-overhead
//! microbenchmark backing the §4.2 overhead table. The routing hot path
//! must stay far below estimator and inference costs.

use ecore::router::{
    GreedyRouter, GroupRules, PairKey, PairProfile, Policy, PolicyKind,
    ProfileStore, RoutingView,
};
use ecore::util::bench::{black_box, Bench};
use ecore::util::rng::Rng;

fn synthetic_store(pairs: usize, groups: usize) -> ProfileStore {
    let mut rng = Rng::new(42);
    let mut rows = Vec::new();
    for p in 0..pairs {
        for g in 0..groups {
            rows.push(PairProfile {
                pair: PairKey::new(&format!("model{p}"), &format!("dev{p}")),
                group: g,
                map: rng.range(10.0, 60.0),
                latency_s: rng.range(0.005, 0.5),
                energy_mwh: rng.range(0.001, 0.1),
            });
        }
    }
    ProfileStore::new(rows)
}

fn main() {
    let mut b = Bench::new("routing");

    // Algorithm 1 at deployed-pool scale (the production case).
    // The `route` wrapper still clones the winning PairKey; the
    // `_view` rows below are the gateway's actual zero-allocation
    // hot path (borrowed view, copyable PairId out).
    let store = synthetic_store(7, 5);
    let greedy = GreedyRouter::new(5.0);
    let mut g = 0usize;
    b.run("greedy_pool7", || {
        g = (g + 1) % 5;
        black_box(greedy.route(&store, g))
    });
    let view = RoutingView::new(&store);
    b.run("greedy_pool7_view", || {
        g = (g + 1) % 5;
        black_box(greedy.route_view(&view, g))
    });

    // Algorithm 1 over the full 64-pair grid
    let store64 = synthetic_store(64, 5);
    b.run("greedy_grid64", || {
        g = (g + 1) % 5;
        black_box(greedy.route(&store64, g))
    });
    let view64 = RoutingView::new(&store64);
    b.run("greedy_grid64_view", || {
        g = (g + 1) % 5;
        black_box(greedy.route_view(&view64, g))
    });

    // every baseline policy at pool scale, on the hot (view) path
    for kind in [
        PolicyKind::RoundRobin,
        PolicyKind::Random,
        PolicyKind::LowestEnergy,
        PolicyKind::LowestInference,
        PolicyKind::HighestMap,
        PolicyKind::HighestMapPerGroup,
    ] {
        let mut policy = Policy::new(kind, &store, 5.0, 7);
        let name = format!("policy_{}", kind.label());
        b.run(&name, || {
            g = (g + 1) % 5;
            black_box(policy.route_view(&view, g))
        });
    }

    // group rule lookup
    let rules = GroupRules::paper_default();
    let mut c = 0usize;
    b.run("group_lookup", || {
        c = (c + 1) % 23;
        black_box(rules.group_of(c))
    });

    // headline: routes/sec on the hot path (median-derived)
    let extras: Vec<(String, f64)> = b
        .results()
        .iter()
        .filter(|r| r.name.ends_with("_view") || r.name.starts_with("policy_"))
        .map(|r| {
            (format!("routes_per_sec_{}", r.name), r.throughput_per_sec())
        })
        .collect();
    b.finish_json(&extras);
}
