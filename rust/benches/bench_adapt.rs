//! Adaptation-subsystem throughput: full open-loop discrete-event runs
//! over the real deployed testbed with device drift on, in three
//! regimes — adaptation off (the drift-only baseline), continuous
//! telemetry feedback, and feedback plus the energy-proportional
//! scaler. The spread against the baseline is the pure cost of the
//! per-completion EWMA update, the overlay republish, and the
//! scale-tick train.

use std::time::Instant;

use ecore::adapt::AdaptConfig;
use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::devices::drift::DriftConfig;
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::nodes::NodePool;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::{
    run_frames, ArrivalProcess, OpenLoopConfig,
};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig {
        profile_per_group: if quick { 6 } else { 12 },
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let mut b = Bench::new("adapt");
    let mut extras: Vec<(String, f64)> = Vec::new();
    for (name, adapt) in [
        ("adapt_off", None),
        (
            "telemetry",
            Some(AdaptConfig { scale: false, ..Default::default() }),
        ),
        (
            "telemetry_scaler",
            Some(AdaptConfig { scale: true, ..Default::default() }),
        ),
    ] {
        let run_once = || {
            let pool = NodePool::deploy(
                &h.engine,
                &deployed.pairs(),
                &ecore::devices::fleet(),
                1,
            )
            .unwrap();
            let mut gw = Gateway::new(
                &h.engine,
                router_by_name("ED").unwrap(),
                deployed.clone(),
                pool,
                5.0,
                1,
            );
            gw.pool_mut().enable_drift(&DriftConfig::default(), 7);
            run_frames(
                &mut gw,
                &frames,
                &gts,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 500.0 },
                    queue_capacity: 8,
                    seed: 3,
                    churn: None,
                    slo: None,
                    adapt: adapt.clone(),
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
        };
        // warm-up + event census (deterministic per config/seed)
        let t0 = Instant::now();
        let report = run_once();
        let cold_wall = t0.elapsed().as_secs_f64();
        let events = report.offered + report.metrics.requests;
        println!(
            "{:<16} {:>10.0} events/sec cold ({} events, {} served, {} samples, {} downs/{} ups)",
            name,
            events as f64 / cold_wall.max(1e-9),
            events,
            report.metrics.requests,
            report
                .adapt
                .as_ref()
                .map(|a| a.telemetry_samples)
                .unwrap_or(0),
            report.adapt.as_ref().map(|a| a.power_downs).unwrap_or(0),
            report.adapt.as_ref().map(|a| a.power_ups).unwrap_or(0),
        );
        b.run(name, || {
            let report = run_once();
            black_box(report.metrics.requests + report.dropped)
        });
        // headline events/sec from the MEASURED MEDIAN run time (the
        // cold run above is warm-up, not the tracked number)
        let runs_per_sec = b
            .results()
            .last()
            .expect("case just measured")
            .throughput_per_sec();
        extras.push((
            format!("events_per_sec_{name}"),
            events as f64 * runs_per_sec,
        ));
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish_json(&extras);
}
