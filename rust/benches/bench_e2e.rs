//! End-to-end request latency through the gateway: scene render →
//! estimate → route → PJRT inference → decode → metrics. One case per
//! router configuration over the real deployed testbed, plus per-model
//! raw inference costs — the numbers behind EXPERIMENTS.md §Perf.

use ecore::config::ExperimentConfig;
use ecore::dataset::{scene, SceneSpec};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::metrics::RunMetrics;
use ecore::nodes::NodePool;
use ecore::util::bench::{black_box, Bench};

fn main() {
    let cfg = ExperimentConfig {
        profile_per_group: 12,
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let mut b = Bench::new("e2e");

    // raw engine inference per model class
    let img = scene::render_spec(&SceneSpec {
        id: 0,
        seed: 3,
        n_objects: 4,
    });
    for model in ["ssd_v1", "effdet_lite2", "yolov8n", "yolov8m"] {
        let name = format!("infer_{model}");
        b.run(&name, || {
            black_box(h.engine.infer(model, &img.image).unwrap())
        });
    }

    // full gateway round-trips
    for router in ["LE", "HMG", "ED", "SF", "OB", "Orc"] {
        let pool = NodePool::deploy(
            &h.engine,
            &deployed.pairs(),
            &ecore::devices::fleet(),
            1,
        )
        .unwrap();
        let mut gw = Gateway::new(
            &h.engine,
            router_by_name(router).unwrap(),
            deployed.clone(),
            pool,
            5.0,
            1,
        );
        let mut m = RunMetrics::new(router);
        let name = format!("gateway_{router}");
        let mut seed = 0u64;
        b.run(&name, || {
            seed += 1;
            let s = scene::render_spec(&SceneSpec {
                id: 0,
                seed,
                n_objects: (seed % 8) as usize,
            });
            black_box(
                gw.handle(&s.image, s.gt.len(), &s.gt, &mut m).unwrap(),
            )
        });
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish();
}
