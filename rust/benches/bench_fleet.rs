//! Fleet simulator throughput: full sharded discrete-event runs
//! (synthesis → dispatch with cross-shard fallback → PJRT service →
//! completion bookkeeping) over the real deployed testbed, at fleet
//! sizes up to 200 nodes / 8 shards and worker-thread counts up to 8
//! (`t1` = sequential shared-heap engine, `tN` = per-shard heaps under
//! the watermark merge). Reports events/sec (arrival + completion
//! events over wall time) per configuration, plus the usual
//! median/p10/p90 table from the in-tree harness.

use std::time::Instant;

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::fleet::parallel::{run_frames_threads, ParallelFleetSpec};
use ecore::fleet::{DispatchPolicy, FleetConfig};
use ecore::gateway::router_by_name;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::ArrivalProcess;

fn main() {
    // CI perf-smoke runs with `--quick`: smaller profiling set and
    // fewer fleet shapes, same JSON trajectory format.
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig {
        profile_per_group: if quick { 6 } else { 12 },
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    // (nodes, shards, dispatch, threads): every fleet shape is
    // measured at threads=1 (the sequential engine) and at least one
    // parallel width, so BENCH_fleet.json always carries the
    // single-thread baseline next to the scaled numbers.
    let full_shapes = [
        (24, 2, DispatchPolicy::LeastLoaded, 1),
        (24, 2, DispatchPolicy::LeastLoaded, 4),
        (96, 8, DispatchPolicy::LeastLoaded, 1),
        (96, 8, DispatchPolicy::LeastLoaded, 2),
        (96, 8, DispatchPolicy::LeastLoaded, 4),
        (96, 8, DispatchPolicy::LeastLoaded, 8),
        (96, 8, DispatchPolicy::Hash, 4),
        (200, 8, DispatchPolicy::LeastLoaded, 1),
        (200, 8, DispatchPolicy::LeastLoaded, 4),
    ];
    let shapes: &[(usize, usize, DispatchPolicy, usize)] =
        if quick { &full_shapes[..2] } else { &full_shapes };

    let mut b = Bench::new("fleet");
    let mut events_per_sec: Vec<(String, f64)> = Vec::new();
    for &(nodes, shards, dispatch, threads) in shapes {
        let name = format!(
            "n{nodes}_k{shards}_{}_t{threads}",
            dispatch.label()
        );
        let run_once = || {
            run_frames_threads(
                &ParallelFleetSpec {
                    artifacts_dir: h.artifacts_dir(),
                    base: &deployed,
                    spec: router_by_name("ED").unwrap(),
                    delta_map: 5.0,
                },
                &FleetConfig {
                    n_nodes: nodes,
                    n_shards: shards,
                    perturb: 0.15,
                    queue_capacity: 8,
                    dispatch,
                    n_sources: 32,
                    seed: 1,
                    drift: None,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                    threads,
                },
                &frames,
                &gts,
                &ArrivalProcess::Poisson { rate_rps: 400.0 },
                3,
            )
            .unwrap()
        };
        // warm-up + event census (deterministic per config/seed), for
        // the events/sec headline and the printed breakdown
        let t0 = Instant::now();
        let report = run_once();
        let cold_wall = t0.elapsed().as_secs_f64();
        let events = report.offered + report.requests();
        println!(
            "{:<24} {:>10.0} events/sec cold  ({} events: {} served, {} dropped, xshard {})",
            name,
            events as f64 / cold_wall.max(1e-9),
            events,
            report.requests(),
            report.dropped,
            report.cross_shard_fallbacks
        );
        b.run(&name, || black_box(run_once().requests()));
        // headline: simulator events per wall second (one arrival per
        // offered request + one completion per served), derived from
        // the MEASURED MEDIAN run time — not the cold first run — so
        // the tracked trajectory is not biased by build/warm-up cost
        let runs_per_sec = b
            .results()
            .last()
            .expect("case just measured")
            .throughput_per_sec();
        events_per_sec.push((
            format!("events_per_sec_{name}"),
            events as f64 * runs_per_sec,
        ));
    }

    // Sim runs execute on per-worker engines (even at t1), so the
    // harness engine's totals cover profiling only.
    let (secs, count) = h.engine.exec_stats();
    println!(
        "harness engine totals (profiling): {count} inferences, \
         {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish_json(&events_per_sec);
}
