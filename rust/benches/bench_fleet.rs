//! Fleet simulator throughput: full sharded discrete-event runs
//! (synthesis → dispatch with cross-shard fallback → PJRT service →
//! completion bookkeeping) over the real deployed testbed, at fleet
//! sizes up to 200 nodes / 8 shards. Reports events/sec (arrival +
//! completion events over wall time) per configuration, plus the usual
//! median/p10/p90 table from the in-tree harness.

use std::time::Instant;

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::fleet::{run_frames, DispatchPolicy, FleetBuilder, FleetConfig};
use ecore::gateway::router_by_name;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::ArrivalProcess;

fn main() {
    let cfg = ExperimentConfig {
        profile_per_group: 12,
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let mut b = Bench::new("fleet");
    for (nodes, shards, dispatch) in [
        (24, 2, DispatchPolicy::LeastLoaded),
        (96, 8, DispatchPolicy::LeastLoaded),
        (96, 8, DispatchPolicy::Hash),
        (200, 8, DispatchPolicy::LeastLoaded),
    ] {
        let name = format!("n{nodes}_k{shards}_{}", dispatch.label());
        let run_once = || {
            let mut fl = FleetBuilder::new(&h.engine, deployed.clone())
                .build(
                    router_by_name("ED").unwrap(),
                    5.0,
                    &FleetConfig {
                        n_nodes: nodes,
                        n_shards: shards,
                        perturb: 0.15,
                        queue_capacity: 8,
                        dispatch,
                        n_sources: 32,
                        seed: 1,
                        drift: None,
                        churn: None,
                    },
                )
                .unwrap();
            run_frames(
                &mut fl,
                &frames,
                &gts,
                &ArrivalProcess::Poisson { rate_rps: 400.0 },
                3,
            )
            .unwrap()
        };
        // headline number: simulator events processed per wall second
        // (one arrival per offered request + one completion per served)
        let t0 = Instant::now();
        let report = run_once();
        let wall = t0.elapsed().as_secs_f64();
        let events = report.offered + report.requests();
        println!(
            "{:<24} {:>10.0} events/sec  ({} events: {} served, {} dropped, xshard {})",
            name,
            events as f64 / wall.max(1e-9),
            events,
            report.requests(),
            report.dropped,
            report.cross_shard_fallbacks
        );
        b.run(&name, || black_box(run_once().requests()));
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish();
}
