//! Campaign simulator throughput: full sharded discrete-event runs
//! with the failure-campaign layer active — seeded domain-wide outage
//! schedules, shard-gateway kills with deterministic re-homing, and
//! adoption-driven membership bootstraps, on top of probe-driven
//! churn. The spread against the plain churn row is the pure cost of
//! the campaign machinery (plan merge, domain marks, release/adopt
//! bookkeeping); the gateway row adds the failover path. Each case is
//! measured at threads=1 (sequential shared-heap) and threads=4
//! (per-shard heaps under the watermark merge).

use std::time::Instant;

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::fleet::parallel::{run_frames_threads, ParallelFleetSpec};
use ecore::fleet::{DispatchPolicy, FleetConfig};
use ecore::gateway::router_by_name;
use ecore::lifecycle::campaign::CampaignConfig;
use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::ArrivalProcess;

fn churn_cfg() -> ChurnConfig {
    ChurnConfig {
        mtbf_s: 0.8,
        mttr_s: 0.2,
        probe_interval_s: 0.05,
        probe_timeout_s: 0.02,
        suspect_after: 1,
        policy: ResiliencePolicy::Retry { budget: 4 },
        retry_backoff_s: 0.05,
        horizon_slack_s: 2.0,
        ..Default::default()
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig {
        profile_per_group: if quick { 6 } else { 12 },
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let domains_only = CampaignConfig {
        domain_size: 3,
        domain_mtbf_s: 0.4,
        domain_mttr_s: 0.15,
        gateway_mtbf_s: f64::INFINITY,
        gateway_mttr_s: 0.1,
        seed: 23,
    };
    let with_gateways = CampaignConfig {
        gateway_mtbf_s: 0.5,
        gateway_mttr_s: 0.15,
        ..domains_only.clone()
    };
    let full_cases = [
        ("churn_only", None, 1usize),
        ("domains", Some(domains_only.clone()), 1),
        ("domains_t4", Some(domains_only), 4),
        ("gateways", Some(with_gateways.clone()), 1),
        ("gateways_t4", Some(with_gateways), 4),
    ];
    let cases: &[(&str, Option<CampaignConfig>, usize)] =
        if quick { &full_cases[..2] } else { &full_cases };

    let mut b = Bench::new("campaign");
    let mut extras_owned: Vec<(String, f64)> = Vec::new();
    for (name, campaign, threads) in cases {
        let run_once = || {
            run_frames_threads(
                &ParallelFleetSpec {
                    artifacts_dir: h.artifacts_dir(),
                    base: &deployed,
                    spec: router_by_name("ED").unwrap(),
                    delta_map: 5.0,
                },
                &FleetConfig {
                    n_nodes: 12,
                    n_shards: 3,
                    perturb: 0.15,
                    queue_capacity: 8,
                    dispatch: DispatchPolicy::LeastLoaded,
                    n_sources: 16,
                    seed: 1,
                    drift: None,
                    churn: Some(churn_cfg()),
                    slo: None,
                    adapt: None,
                    campaign: campaign.clone(),
                    obs: None,
                    threads: *threads,
                },
                &frames,
                &gts,
                &ArrivalProcess::Poisson { rate_rps: 400.0 },
                3,
            )
            .unwrap()
        };
        // warm-up + event census (deterministic per config/seed)
        let t0 = Instant::now();
        let report = run_once();
        let cold_wall = t0.elapsed().as_secs_f64();
        let events = report.offered + report.requests();
        let (outages, kills) = report
            .campaign
            .as_ref()
            .map_or((0, 0), |c| (c.domain_outages, c.gw_kills));
        println!(
            "{:<14} {:>10.0} events/sec cold  ({} events, {} outages, {} gw kills)",
            name,
            events as f64 / cold_wall.max(1e-9),
            events,
            outages,
            kills
        );
        b.run(name, || black_box(run_once().requests()));
        // headline events/sec from the MEASURED MEDIAN run time (the
        // cold run above is warm-up, not the tracked number)
        let runs_per_sec = b
            .results()
            .last()
            .expect("case just measured")
            .throughput_per_sec();
        extras_owned.push((
            format!("events_per_sec_{name}"),
            events as f64 * runs_per_sec,
        ));
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "harness engine totals (profiling): {count} inferences, \
         {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish_json(&extras_owned);
}
