//! mAP evaluator throughput: the profiler's inner loop evaluates
//! 8 models x 4 scales x 5 groups, so evaluation speed bounds how large
//! the profiling sets can be.

use ecore::dataset::GtBox;
use ecore::detection::map::{map_coco, ImageEval};
use ecore::detection::{BBox, Detection};
use ecore::util::bench::{black_box, Bench};
use ecore::util::rng::Rng;

fn synth_images(n_images: usize, objs: usize, seed: u64) -> Vec<ImageEval> {
    let mut rng = Rng::new(seed);
    (0..n_images)
        .map(|_| {
            let gt: Vec<GtBox> = (0..objs)
                .map(|_| {
                    let x = rng.range(20.0, 350.0);
                    let y = rng.range(20.0, 350.0);
                    let r = rng.range(6.0, 24.0);
                    GtBox {
                        x0: x - r,
                        y0: y - r,
                        x1: x + r,
                        y1: y + r,
                        cls: rng.below(2) as usize,
                    }
                })
                .collect();
            // predictions: noisy copies of GT + 1 false positive
            let mut dets: Vec<Detection> = gt
                .iter()
                .map(|g| Detection {
                    bbox: BBox::new(
                        g.x0 + rng.range(-3.0, 3.0),
                        g.y0 + rng.range(-3.0, 3.0),
                        g.x1 + rng.range(-3.0, 3.0),
                        g.y1 + rng.range(-3.0, 3.0),
                    ),
                    score: rng.f32(),
                    cls: g.cls,
                })
                .collect();
            dets.push(Detection {
                bbox: BBox::new(1.0, 1.0, 12.0, 12.0),
                score: rng.f32() * 0.3,
                cls: 0,
            });
            ImageEval { dets, gt }
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("map");
    for (name, images, objs) in [
        ("50img_x3obj", 50, 3),
        ("200img_x3obj", 200, 3),
        ("50img_x10obj", 50, 10),
    ] {
        let evals = synth_images(images, objs, 11);
        b.run(name, || black_box(map_coco(&evals, 2).map));
    }
    b.finish();
}
