//! Estimator cost per image: ED (canny artifact + contour counting),
//! SF (front-end detector + decode), OB/Oracle (free). These are the
//! real-wall-clock counterparts of the simulated gateway-overhead
//! figures.

use ecore::dataset::{scene, SceneSpec};
use ecore::devices::gateway_spec;
use ecore::estimators::{ed, Estimator, EstimatorKind};
use ecore::runtime::Engine;
use ecore::util::bench::{black_box, Bench};

fn main() {
    let engine = Engine::new(&ecore::default_artifacts_dir()).unwrap();
    let gw = gateway_spec();
    let mut b = Bench::new("estimators");

    let sparse = scene::render_spec(&SceneSpec {
        id: 0,
        seed: 7,
        n_objects: 1,
    });
    let crowded = scene::render_spec(&SceneSpec {
        id: 1,
        seed: 8,
        n_objects: 8,
    });

    for kind in [
        EstimatorKind::Oracle,
        EstimatorKind::OutputBased,
        EstimatorKind::EdgeDetection,
        EstimatorKind::SsdFront,
    ] {
        let mut est = Estimator::new(kind);
        let name = format!("{}_sparse", kind.label());
        b.run(&name, || {
            black_box(
                est.estimate(&engine, &gw, &sparse.image, 1).unwrap(),
            )
        });
        let mut est = Estimator::new(kind);
        let name = format!("{}_crowded", kind.label());
        b.run(&name, || {
            black_box(
                est.estimate(&engine, &gw, &crowded.image, 8).unwrap(),
            )
        });
    }

    // contour counting alone (the non-HLO part of ED)
    let edges = engine.infer("canny", &crowded.image).unwrap();
    let cfg = ed::EdConfig::default();
    b.run("ed_count_contours", || {
        black_box(ed::count_contours(&edges, 96, &cfg))
    });

    b.finish();
}
