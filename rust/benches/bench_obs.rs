//! Observability-layer overhead: full open-loop discrete-event runs
//! over the real deployed testbed at a saturating arrival rate, with
//! the obs layer off (the `bench_openloop`-equivalent baseline), on at
//! the default 50 ms series tick, and on at an aggressive 5 ms tick.
//! Collection runs with an empty `out_dir` (collect-only mode) so the
//! spread against the baseline is the pure cost of span folding and
//! series bucketing, with no filesystem noise.

use std::time::Instant;

use ecore::config::ExperimentConfig;
use ecore::dataset::{coco, GtBox, Scene};
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::nodes::NodePool;
use ecore::obs::ObsConfig;
use ecore::util::bench::{black_box, Bench};
use ecore::workload::openloop::{
    run_frames, ArrivalProcess, OpenLoopConfig,
};

/// Collect-only obs config at the given series tick.
fn obs_at(tick_s: f64) -> ObsConfig {
    ObsConfig {
        tick_s,
        span_head: 32,
        span_tail: 32,
        span_sample: 64,
        seed: 7,
        out_dir: String::new(),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig {
        profile_per_group: if quick { 6 } else { 12 },
        ..Default::default()
    };
    let h = Harness::new(cfg).unwrap();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(24, 7);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();

    let mut b = Bench::new("obs");
    let mut extras: Vec<(String, f64)> = Vec::new();
    for (name, obs) in [
        ("obs_off", None),
        ("obs_on_50ms", Some(obs_at(0.05))),
        ("obs_on_5ms", Some(obs_at(0.005))),
    ] {
        let run_once = || {
            let pool = NodePool::deploy(
                &h.engine,
                &deployed.pairs(),
                &ecore::devices::fleet(),
                1,
            )
            .unwrap();
            let mut gw = Gateway::new(
                &h.engine,
                router_by_name("ED").unwrap(),
                deployed.clone(),
                pool,
                5.0,
                1,
            );
            run_frames(
                &mut gw,
                &frames,
                &gts,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 500.0 },
                    queue_capacity: 8,
                    seed: 3,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: obs.clone(),
                },
            )
            .unwrap()
        };
        // warm-up + event census (deterministic per config/seed)
        let t0 = Instant::now();
        let report = run_once();
        let cold_wall = t0.elapsed().as_secs_f64();
        let events = report.offered + report.metrics.requests;
        println!(
            "{:<14} {:>10.0} events/sec cold ({} events, {} served, {} dropped)",
            name,
            events as f64 / cold_wall.max(1e-9),
            events,
            report.metrics.requests,
            report.dropped,
        );
        b.run(name, || {
            let report = run_once();
            black_box(report.metrics.requests + report.dropped)
        });
        // headline events/sec from the MEASURED MEDIAN run time (the
        // cold run above is warm-up, not the tracked number)
        let runs_per_sec = b
            .results()
            .last()
            .expect("case just measured")
            .throughput_per_sec();
        extras.push((
            format!("events_per_sec_{name}"),
            events as f64 * runs_per_sec,
        ));
    }

    let (secs, count) = h.engine.exec_stats();
    println!(
        "engine totals: {count} inferences, {:.1} ms mean",
        1000.0 * secs / count.max(1) as f64
    );
    b.finish_json(&extras);
}
