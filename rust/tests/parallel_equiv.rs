//! Parallel-engine equivalence tests: the per-shard event engine with
//! the watermark merge (DESIGN.md §13) must produce a report that
//! serializes byte-for-byte identically to the sequential shared-heap
//! engine, at every worker count, for every feature combination the
//! simulator supports (plain, churn under each resilience policy, SLO
//! batching, drift + adaptation) and across a randomized config sweep.
//!
//! `threads: 1` runs the exact sequential code path, so comparing the
//! `threads: N` dump against the `threads: 1` dump of the same config
//! is a direct sequential-vs-parallel equivalence check, not a
//! parallel-vs-parallel consistency check.

use ecore::adapt::AdaptConfig;
use ecore::dataset::{GtBox, Scene};
use ecore::devices::drift::DriftConfig;
use ecore::fleet::parallel::{run_frames_threads, ParallelFleetSpec};
use ecore::fleet::{DispatchPolicy, FleetConfig, FleetReport};
use ecore::gateway::router_by_name;
use ecore::lifecycle::campaign::CampaignConfig;
use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};
use ecore::router::{PairKey, PairProfile, ProfileStore};
use ecore::workload::openloop::ArrivalProcess;

fn base_store() -> ProfileStore {
    let mut rows = Vec::new();
    for g in 0..5 {
        rows.push(PairProfile {
            pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
            group: g,
            map: 50.0,
            latency_s: 0.005,
            energy_mwh: 0.002,
        });
        rows.push(PairProfile {
            pair: PairKey::new("yolov8n", "pi5"),
            group: g,
            map: if g >= 2 { 75.0 } else { 51.0 },
            latency_s: 0.05,
            energy_mwh: 0.05,
        });
    }
    ProfileStore::new(rows)
}

/// One run of the given config through the thread-count entry point,
/// serialized. Frames and the arrival process are derived from the
/// seeds so every call with equal arguments sees an identical offered
/// load.
fn run_report(
    router: &str,
    images: usize,
    ds_seed: u64,
    cfg: &FleetConfig,
    rate_rps: f64,
    run_seed: u64,
) -> FleetReport {
    let ds = ecore::dataset::coco::build(images, ds_seed);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let artifacts = ecore::default_artifacts_dir();
    let base = base_store();
    run_frames_threads(
        &ParallelFleetSpec {
            artifacts_dir: &artifacts,
            base: &base,
            spec: router_by_name(router).unwrap(),
            delta_map: 5.0,
        },
        cfg,
        &frames,
        &gts,
        &ArrivalProcess::Poisson { rate_rps },
        run_seed,
    )
    .unwrap()
}

fn dump(
    router: &str,
    images: usize,
    ds_seed: u64,
    cfg: &FleetConfig,
    rate_rps: f64,
    run_seed: u64,
) -> String {
    run_report(router, images, ds_seed, cfg, rate_rps, run_seed)
        .to_json()
        .pretty()
}

/// Assert the `threads: 1` (sequential) dump equals the dump at every
/// requested worker count.
fn assert_equiv(
    label: &str,
    router: &str,
    images: usize,
    ds_seed: u64,
    cfg: &FleetConfig,
    rate_rps: f64,
    run_seed: u64,
) {
    let seq = FleetConfig { threads: 1, ..cfg.clone() };
    let want = dump(router, images, ds_seed, &seq, rate_rps, run_seed);
    for threads in [2usize, 4] {
        let par = FleetConfig { threads, ..cfg.clone() };
        let got =
            dump(router, images, ds_seed, &par, rate_rps, run_seed);
        assert_eq!(
            want, got,
            "[{label}] threads={threads} diverged from sequential"
        );
    }
}

fn plain_cfg(n_nodes: usize, n_shards: usize) -> FleetConfig {
    FleetConfig {
        n_nodes,
        n_shards,
        perturb: 0.15,
        queue_capacity: 2,
        dispatch: DispatchPolicy::LeastLoaded,
        n_sources: 4,
        seed: 11,
        drift: None,
        churn: None,
        slo: None,
        adapt: None,
        campaign: None,
        obs: None,
        threads: 1,
    }
}

fn churn_cfg(policy: ResiliencePolicy) -> ChurnConfig {
    ChurnConfig {
        mtbf_s: 0.12,
        mttr_s: 0.15,
        probe_interval_s: 0.04,
        probe_timeout_s: 0.02,
        suspect_after: 1,
        warmup_s: 0.1,
        warmup_penalty: 0.5,
        policy,
        retry_backoff_s: 0.04,
        hedge_cancel: false,
        horizon_slack_s: 1.0,
        seed: 37,
    }
}

#[test]
fn plain_fleet_matches_sequential() {
    assert_equiv(
        "plain",
        "OB",
        14,
        55,
        &plain_cfg(12, 3),
        120.0,
        9,
    );
}

#[test]
fn hash_dispatch_matches_sequential() {
    let cfg = FleetConfig {
        dispatch: DispatchPolicy::Hash,
        ..plain_cfg(12, 4)
    };
    assert_equiv("hash", "ED", 14, 21, &cfg, 150.0, 13);
}

#[test]
fn sticky_dispatch_matches_sequential() {
    let cfg = FleetConfig {
        dispatch: DispatchPolicy::Sticky,
        ..plain_cfg(8, 2)
    };
    assert_equiv("sticky", "LE", 14, 33, &cfg, 150.0, 17);
}

#[test]
fn churn_retry_matches_sequential() {
    let cfg = FleetConfig {
        churn: Some(churn_cfg(ResiliencePolicy::Retry { budget: 3 })),
        ..plain_cfg(6, 2)
    };
    assert_equiv("churn-retry", "LE", 16, 77, &cfg, 200.0, 31);
}

#[test]
fn churn_hedge_matches_sequential() {
    let cfg = FleetConfig {
        churn: Some(churn_cfg(ResiliencePolicy::Hedge)),
        ..plain_cfg(6, 2)
    };
    assert_equiv("churn-hedge", "LE", 16, 78, &cfg, 200.0, 32);
}

#[test]
fn churn_drop_matches_sequential() {
    let cfg = FleetConfig {
        churn: Some(churn_cfg(ResiliencePolicy::Drop)),
        ..plain_cfg(6, 3)
    };
    assert_equiv("churn-drop", "ED", 16, 79, &cfg, 200.0, 33);
}

#[test]
fn slo_batching_matches_sequential() {
    let cfg = FleetConfig {
        queue_capacity: 4,
        slo: Some(ecore::workload::slo::SloConfig::default()),
        ..plain_cfg(6, 2)
    };
    assert_equiv("slo", "LE", 18, 83, &cfg, 220.0, 47);
}

#[test]
fn adapt_with_drift_matches_sequential() {
    let cfg = FleetConfig {
        queue_capacity: 4,
        drift: Some(DriftConfig::default()),
        adapt: Some(AdaptConfig {
            scale_interval_s: 0.05,
            ..Default::default()
        }),
        ..plain_cfg(6, 2)
    };
    assert_equiv("adapt", "LE", 16, 67, &cfg, 200.0, 59);
}

#[test]
fn everything_on_matches_sequential() {
    // Churn + SLO + adaptation + drift simultaneously: every event
    // kind the simulator knows is in flight at once.
    let cfg = FleetConfig {
        queue_capacity: 3,
        drift: Some(DriftConfig::default()),
        churn: Some(churn_cfg(ResiliencePolicy::Retry { budget: 2 })),
        slo: Some(ecore::workload::slo::SloConfig::default()),
        adapt: Some(AdaptConfig {
            scale_interval_s: 0.05,
            ..Default::default()
        }),
        ..plain_cfg(8, 4)
    };
    assert_equiv("everything", "ED", 18, 91, &cfg, 240.0, 61);
}

fn campaign_cfg(
    domain_size: usize,
    domain_mtbf_s: f64,
    gateway_mtbf_s: f64,
) -> CampaignConfig {
    CampaignConfig {
        domain_size,
        domain_mtbf_s,
        domain_mttr_s: 0.1,
        gateway_mtbf_s,
        gateway_mttr_s: 0.12,
        seed: 41,
    }
}

#[test]
fn campaign_domains_match_sequential() {
    // Domain-wide outages layered on per-node churn: correlated
    // crash/restore bursts plus the independent flips, merged into
    // one plan, must replay identically from the per-shard heaps.
    let cfg = FleetConfig {
        churn: Some(churn_cfg(ResiliencePolicy::Retry { budget: 2 })),
        campaign: Some(campaign_cfg(3, 0.2, f64::INFINITY)),
        ..plain_cfg(9, 3)
    };
    assert_equiv("campaign-domains", "LE", 16, 71, &cfg, 200.0, 43);
}

#[test]
fn campaign_gateway_failover_matches_sequential() {
    // Gateway kills force deterministic re-homing: orphans adopted by
    // surviving shards, membership bootstrapped from scratch, then
    // re-adopted on recovery — none of which may depend on the worker
    // count.
    let cfg = FleetConfig {
        churn: Some(churn_cfg(ResiliencePolicy::Retry { budget: 2 })),
        campaign: Some(campaign_cfg(3, 0.35, 0.25)),
        ..plain_cfg(9, 3)
    };
    assert_equiv("campaign-gateway", "ED", 16, 72, &cfg, 200.0, 44);
}

#[test]
fn hedge_cancellation_matches_sequential() {
    // Cancellation-on-first-response mutates the losing sibling's
    // node mid-flight (slot release + partial energy charge); the
    // effect order is pinned, so dumps must stay bit-identical.
    let cfg = FleetConfig {
        churn: Some(ChurnConfig {
            hedge_cancel: true,
            ..churn_cfg(ResiliencePolicy::Hedge)
        }),
        ..plain_cfg(6, 2)
    };
    assert_equiv("hedge-cancel", "LE", 16, 78, &cfg, 200.0, 32);
}

#[test]
fn campaign_ledger_invariant_under_randomized_schedules() {
    // Property: `offered == served + dropped + lost` survives any
    // campaign shape (domain-only, gateway-only, both), any
    // resilience policy, with and without hedge cancellation, at
    // every worker count. A campaign may black out whole shards but
    // no request may vanish from the conservation ledger.
    let mut z: u64 = 0x0CA4_5EED_0BAD_CAFE;
    let mut next = move || {
        z ^= z << 13;
        z ^= z >> 7;
        z ^= z << 17;
        z
    };
    for round in 0..6u64 {
        let policy = match round % 3 {
            0 => ResiliencePolicy::Drop,
            1 => ResiliencePolicy::Retry { budget: 2 },
            _ => ResiliencePolicy::Hedge,
        };
        let camp = CampaignConfig {
            domain_size: 2 + (next() % 3) as usize,
            domain_mtbf_s: if next() % 4 == 0 {
                f64::INFINITY
            } else {
                0.1 + 0.05 * (next() % 4) as f64
            },
            domain_mttr_s: 0.08,
            gateway_mtbf_s: if next() % 2 == 0 {
                0.3
            } else {
                f64::INFINITY
            },
            gateway_mttr_s: 0.1,
            seed: next(),
        };
        let n_shards = 2 + (round % 2) as usize;
        let cfg = FleetConfig {
            churn: Some(ChurnConfig {
                hedge_cancel: next() % 2 == 0,
                ..churn_cfg(policy)
            }),
            campaign: Some(camp),
            ..plain_cfg(4 * n_shards, n_shards)
        };
        let ds_seed = next();
        let run_seed = next();
        for threads in [1usize, 4] {
            let report = run_report(
                "ED",
                14,
                ds_seed,
                &FleetConfig { threads, ..cfg.clone() },
                180.0,
                run_seed,
            );
            let lost =
                report.churn.as_ref().map_or(0, |c| c.lost);
            assert_eq!(
                report.offered,
                report.requests() + report.dropped + lost,
                "round {round} threads {threads}: ledger violated \
                 (served {} dropped {} lost {lost} of {} offered)",
                report.requests(),
                report.dropped,
                report.offered
            );
        }
    }
}

#[test]
fn randomized_config_sweep_matches_sequential() {
    // A deterministic xorshift walk over fleet shapes, dispatch
    // policies, and feature toggles. Each drawn config is compared
    // threads=1 vs threads∈{2,4}; the draw is seeded so failures
    // reproduce.
    let mut z: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut next = move || {
        z ^= z << 13;
        z ^= z >> 7;
        z ^= z << 17;
        z
    };
    for round in 0..5u64 {
        let n_shards = 1 + (next() % 4) as usize;
        let n_nodes = n_shards * (1 + (next() % 3) as usize);
        let dispatch = match next() % 3 {
            0 => DispatchPolicy::Hash,
            1 => DispatchPolicy::LeastLoaded,
            _ => DispatchPolicy::Sticky,
        };
        let policy = match next() % 4 {
            0 => Some(ResiliencePolicy::Drop),
            1 => Some(ResiliencePolicy::Retry { budget: 2 }),
            2 => Some(ResiliencePolicy::Hedge),
            _ => None,
        };
        let cfg = FleetConfig {
            n_nodes,
            n_shards,
            perturb: 0.1 + 0.05 * (next() % 3) as f64,
            queue_capacity: 2 + (next() % 3) as usize,
            dispatch,
            n_sources: 3 + (next() % 5) as usize,
            seed: next(),
            drift: None,
            churn: policy.map(churn_cfg),
            slo: if next() % 2 == 0 {
                Some(ecore::workload::slo::SloConfig::default())
            } else {
                None
            },
            adapt: None,
            campaign: None,
            obs: None,
            threads: 1,
        };
        let rate = 80.0 + 40.0 * (next() % 4) as f64;
        let label = format!(
            "sweep round {round}: {n_nodes}n/{n_shards}k {} {:?}",
            cfg.dispatch.label(),
            cfg.churn.as_ref().map(|c| c.policy)
        );
        assert_equiv(&label, "ED", 12, next(), &cfg, rate, next());
    }
}

#[test]
fn obs_export_identical_across_threads() {
    use ecore::obs::ObsConfig;
    // The observability exports must be byte-identical at every
    // worker count, not just the report: per-shard collectors are
    // merged in shard order with the spine last, so the files carry
    // no trace of the thread schedule. Churn + SLO batching keeps
    // every span edge kind (shed/retry/hedge/loss/batch) in play.
    let base_dir = std::env::temp_dir()
        .join(format!("ecore_obs_equiv_{}", std::process::id()));
    let cfg0 = FleetConfig {
        queue_capacity: 3,
        churn: Some(churn_cfg(ResiliencePolicy::Retry { budget: 2 })),
        slo: Some(ecore::workload::slo::SloConfig::default()),
        ..plain_cfg(6, 2)
    };
    const FILES: [&str; 3] =
        ["spans.jsonl", "series.jsonl", "metrics.prom"];
    let mut want: Option<Vec<String>> = None;
    for threads in [1usize, 2, 4] {
        let dir = base_dir.join(format!("t{threads}"));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = FleetConfig {
            threads,
            obs: Some(ObsConfig {
                tick_s: 0.05,
                span_head: 8,
                span_tail: 8,
                span_sample: 16,
                seed: 7,
                out_dir: dir.to_string_lossy().into_owned(),
            }),
            ..cfg0.clone()
        };
        let _ = dump("LE", 16, 77, &cfg, 200.0, 31);
        let got: Vec<String> = FILES
            .iter()
            .map(|f| std::fs::read_to_string(dir.join(f)).unwrap())
            .collect();
        assert!(
            got.iter().any(|s| !s.is_empty()),
            "threads={threads}: all exports empty"
        );
        match &want {
            None => want = Some(got),
            Some(w) => {
                for (name, (a, b)) in
                    FILES.iter().zip(w.iter().zip(got.iter()))
                {
                    assert_eq!(
                        a, b,
                        "threads={threads}: {name} diverged"
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&base_dir);
}
