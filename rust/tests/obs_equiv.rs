//! Obs-on/obs-off equivalence: enabling the observability layer must
//! not perturb the simulation. Collectors are passive — they fold
//! copies of event data, schedule no events of their own, and add no
//! report keys — so a run with `obs: Some(..)` (empty `out_dir`, the
//! collect-only mode) must serialize byte-identically to the same run
//! with `obs: None`, in both the open-loop and fleet engines.

use ecore::dataset::{GtBox, Scene};
use ecore::fleet::parallel::{run_frames_threads, ParallelFleetSpec};
use ecore::fleet::{DispatchPolicy, FleetConfig};
use ecore::gateway::{router_by_name, Gateway};
use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};
use ecore::nodes::NodePool;
use ecore::obs::ObsConfig;
use ecore::router::{PairKey, PairProfile, ProfileStore};
use ecore::runtime::Engine;
use ecore::workload::openloop::{self, ArrivalProcess, OpenLoopConfig};

fn base_store() -> ProfileStore {
    let mut rows = Vec::new();
    for g in 0..5 {
        rows.push(PairProfile {
            pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
            group: g,
            map: 50.0,
            latency_s: 0.005,
            energy_mwh: 0.002,
        });
        rows.push(PairProfile {
            pair: PairKey::new("yolov8n", "pi5"),
            group: g,
            map: if g >= 2 { 75.0 } else { 51.0 },
            latency_s: 0.05,
            energy_mwh: 0.05,
        });
    }
    ProfileStore::new(rows)
}

/// Collect-only obs config: empty `out_dir` means the run records
/// spans and series but never touches the filesystem.
fn silent_obs() -> ObsConfig {
    ObsConfig {
        tick_s: 0.05,
        span_head: 8,
        span_tail: 8,
        span_sample: 16,
        seed: 7,
        out_dir: String::new(),
    }
}

fn churn_cfg() -> ChurnConfig {
    ChurnConfig {
        mtbf_s: 0.15,
        mttr_s: 0.2,
        probe_interval_s: 0.05,
        probe_timeout_s: 0.02,
        suspect_after: 1,
        warmup_s: 0.1,
        warmup_penalty: 0.5,
        policy: ResiliencePolicy::Retry { budget: 3 },
        retry_backoff_s: 0.04,
        hedge_cancel: false,
        horizon_slack_s: 1.5,
        seed: 29,
    }
}

/// One fixed-seed open-loop run with churn + SLO batching (so sheds,
/// retries, batches, and deadline accounting all fire), serialized.
fn openloop_dump(obs: Option<ObsConfig>) -> String {
    let e = Engine::new(&ecore::default_artifacts_dir()).unwrap();
    let ds = ecore::dataset::coco::build(14, 99);
    let store = base_store();
    let pool =
        NodePool::deploy(&e, &store.pairs(), &ecore::devices::fleet(), 3)
            .unwrap();
    let mut gw = Gateway::new(
        &e,
        router_by_name("ED").unwrap(),
        store,
        pool,
        5.0,
        3,
    );
    let report = openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 120.0 },
            queue_capacity: 3,
            seed: 17,
            churn: Some(churn_cfg()),
            slo: Some(ecore::workload::slo::SloConfig::default()),
            adapt: None,
            campaign: None,
            obs,
        },
    )
    .unwrap();
    report.to_json().dump()
}

/// One fixed-seed fleet run through the thread-count entry point,
/// serialized.
fn fleet_dump(threads: usize, obs: Option<ObsConfig>) -> String {
    let ds = ecore::dataset::coco::build(16, 77);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let artifacts = ecore::default_artifacts_dir();
    let base = base_store();
    let report = run_frames_threads(
        &ParallelFleetSpec {
            artifacts_dir: &artifacts,
            base: &base,
            spec: router_by_name("LE").unwrap(),
            delta_map: 5.0,
        },
        &FleetConfig {
            n_nodes: 6,
            n_shards: 2,
            perturb: 0.15,
            queue_capacity: 3,
            dispatch: DispatchPolicy::LeastLoaded,
            n_sources: 4,
            seed: 11,
            drift: None,
            churn: Some(churn_cfg()),
            slo: Some(ecore::workload::slo::SloConfig::default()),
            adapt: None,
            campaign: None,
            obs,
            threads,
        },
        &frames,
        &gts,
        &ArrivalProcess::Poisson { rate_rps: 200.0 },
        31,
    )
    .unwrap();
    report.to_json().dump()
}

#[test]
fn openloop_report_identical_with_obs_on() {
    let off = openloop_dump(None);
    let on = openloop_dump(Some(silent_obs()));
    assert_eq!(off, on, "obs layer perturbed the open-loop report");
}

#[test]
fn fleet_report_identical_with_obs_on() {
    for threads in [1usize, 2] {
        let off = fleet_dump(threads, None);
        let on = fleet_dump(threads, Some(silent_obs()));
        assert_eq!(
            off, on,
            "obs layer perturbed the fleet report at threads={threads}"
        );
    }
}
