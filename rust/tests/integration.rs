//! Cross-module integration tests: artifacts → engine → profiler →
//! testbed → gateway → metrics, exercised end to end on small workloads.

use ecore::config::ExperimentConfig;
use ecore::dataset::{balanced, coco, video};
use ecore::devices::fleet;
use ecore::experiments::serve::{
    deployed_store, run_router_on_dataset, run_router_with_delta,
};
use ecore::experiments::Harness;
use ecore::gateway::{paper_routers, router_by_name, Gateway};
use ecore::metrics::RunMetrics;
use ecore::nodes::NodePool;
use ecore::profiling::testbed;
use ecore::router::{PairKey, PairProfile, ProfileStore};
use ecore::runtime::Engine;
use ecore::workload;
use ecore::workload::openloop::{ArrivalProcess, OpenLoopConfig};

/// Tiny hand-built deployment (no profiling grid needed): two pairs
/// covering all five groups, matching the shape used by the workload
/// and openloop module tests.
fn tiny_store() -> ProfileStore {
    let mut rows = Vec::new();
    for g in 0..5 {
        rows.push(PairProfile {
            pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
            group: g,
            map: 50.0,
            latency_s: 0.005,
            energy_mwh: 0.002,
        });
        rows.push(PairProfile {
            pair: PairKey::new("yolov8n", "pi5"),
            group: g,
            map: if g >= 2 { 75.0 } else { 51.0 },
            latency_s: 0.05,
            energy_mwh: 0.05,
        });
    }
    ProfileStore::new(rows)
}

fn tiny_gateway<'e>(e: &'e Engine, router: &str) -> Gateway<'e> {
    let store = tiny_store();
    let pool = NodePool::deploy(e, &store.pairs(), &fleet(), 1).unwrap();
    Gateway::new(e, router_by_name(router).unwrap(), store, pool, 5.0, 1)
}

fn harness() -> Harness {
    // tiny profiling set: fast but structurally faithful
    let cfg = ExperimentConfig {
        profile_per_group: 8,
        coco_images: 30,
        balanced_per_group: 6,
        video_frames: 20,
        seed: 1234,
        ..Default::default()
    };
    Harness::new(cfg).unwrap()
}

#[test]
fn full_pipeline_profiles_selects_and_serves() {
    let h = harness();

    // profiling grid is complete
    let store = h.profiles().unwrap();
    assert_eq!(store.rows().len(), 8 * 8 * 5);
    assert_eq!(store.pairs().len(), 64);

    // testbed selection picks champions incl. the paper's structure
    let rows = testbed::select(&store);
    let energy_champ = rows.iter().find(|r| r.metric == "energy").unwrap();
    assert_eq!(energy_champ.pair.model, "ssd_v1");
    assert_eq!(energy_champ.pair.device, "jetson_orin_nano");
    let latency_champ =
        rows.iter().find(|r| r.metric == "latency").unwrap();
    assert_eq!(latency_champ.pair.device, "pi5_tpu");

    // crowded-scene mAP champion must be a high-capacity model
    let crowded = rows.iter().find(|r| r.metric == "map_g4").unwrap();
    assert!(
        crowded.pair.model.starts_with("yolov8"),
        "crowded champion {:?}",
        crowded.pair
    );

    // serve a small dataset through every router without error
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(10, 42);
    for spec in paper_routers() {
        let m = run_router_on_dataset(&h, spec, &deployed, &ds).unwrap();
        assert_eq!(m.requests, 10, "{}", spec.name);
        assert!(m.total_energy_mwh() > 0.0);
        assert!(m.total_latency_s > 0.0);
    }
}

#[test]
fn paper_shape_holds_on_small_run() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(h.cfg.coco_images, h.cfg.seed);

    let run = |name: &str| {
        run_router_on_dataset(
            &h,
            router_by_name(name).unwrap(),
            &deployed,
            &ds,
        )
        .unwrap()
    };
    let le = run("LE");
    let li = run("LI");
    let hmg = run("HMG");
    let ed = run("ED");

    // LE is the energy lower bound; LI the latency lower bound
    for m in [&li, &hmg, &ed] {
        assert!(m.total_energy_mwh() >= le.total_energy_mwh() * 0.99);
        assert!(m.total_latency_s >= li.total_latency_s * 0.99);
    }
    // HMG beats LE on accuracy by a wide margin
    assert!(hmg.map() > le.map() + 10.0);
    // the proposed ED lands near HMG accuracy at lower energy
    assert!(ed.map() > hmg.map() - 6.0);
    assert!(ed.total_energy_mwh() < hmg.total_energy_mwh());
    // ED pays a gateway overhead, LE doesn't
    assert!(ed.gateway_energy_mwh > 0.0);
    assert_eq!(le.gateway_energy_mwh, 0.0);
}

#[test]
fn delta_relaxation_reduces_energy_monotonically() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(20, 9);
    let spec = router_by_name("Orc").unwrap();
    let mut prev = f64::INFINITY;
    for delta in [0.0, 10.0, 30.0] {
        let m =
            run_router_with_delta(&h, spec, &deployed, &ds, delta).unwrap();
        assert!(
            m.total_energy_mwh() <= prev * 1.05,
            "delta {delta}: energy went up: {} > {prev}",
            m.total_energy_mwh()
        );
        prev = m.total_energy_mwh();
    }
}

#[test]
fn ob_wins_on_sorted_dataset_vs_shuffled() {
    // the paper's Insight #2: OB thrives when consecutive images share
    // object counts. Compare OB estimation error on sorted vs COCO.
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let sorted = balanced::build(6, 3);
    let shuffled = coco::build(30, 3);
    let spec = router_by_name("OB").unwrap();
    let m_sorted =
        run_router_on_dataset(&h, spec, &deployed, &sorted).unwrap();
    let m_shuf =
        run_router_on_dataset(&h, spec, &deployed, &shuffled).unwrap();
    assert!(
        m_sorted.mean_estimation_error() < m_shuf.mean_estimation_error(),
        "sorted {} vs shuffled {}",
        m_sorted.mean_estimation_error(),
        m_shuf.mean_estimation_error()
    );
}

#[test]
fn video_protocol_runs_with_pseudo_labels() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let frames = video::build_frames(h.cfg.video_frames, 5);
    let pseudo = workload::pseudo_annotate(&h.engine, &frames).unwrap();
    let pool =
        NodePool::deploy(&h.engine, &deployed.pairs(), &fleet(), 1).unwrap();
    let mut gw = Gateway::new(
        &h.engine,
        router_by_name("OB").unwrap(),
        deployed,
        pool,
        5.0,
        1,
    );
    let m = workload::run_frames(&mut gw, &frames, &pseudo).unwrap();
    assert_eq!(m.requests, frames.len());
    // OB on temporally-continuous video: small estimation error
    assert!(
        m.mean_estimation_error() < 2.0,
        "estimation error {}",
        m.mean_estimation_error()
    );
    // accuracy against pseudo labels should be solid (the router picks
    // strong models for crowded frames)
    assert!(m.map() > 30.0, "video mAP {}", m.map());
}

#[test]
fn ob_estimator_starts_at_zero_and_lags_by_one_request() {
    // OB semantics (paper §3.3.3): the estimate for request i is the
    // backend detection count of request i-1; the very first request
    // uses the default estimate 0. Checked request by request against
    // the gateway's observed outcomes.
    let e = Engine::new(&ecore::default_artifacts_dir()).unwrap();
    let mut gw = tiny_gateway(&e, "OB");
    let mut m = RunMetrics::new("OB");
    let ds = coco::build(6, 91);
    let mut prev_detections: Option<usize> = None;
    for scene in ds.iter_scenes() {
        let out = gw
            .handle(&scene.image, scene.gt.len(), &scene.gt, &mut m)
            .unwrap();
        match prev_detections {
            None => assert_eq!(out.estimate, 0, "OB must start at 0"),
            Some(prev) => assert_eq!(
                out.estimate, prev,
                "OB estimate must equal the previous response's count"
            ),
        }
        prev_detections = Some(out.detections);
    }
    // OB never runs gateway-side inference
    assert_eq!(m.gateway_energy_mwh, 0.0);
    assert_eq!(m.gateway_latency_s, 0.0);
}

#[test]
fn gateway_cost_is_accounted_exactly_once_per_request() {
    // The estimator's GatewayCost is charged at route() time and must
    // land in RunMetrics exactly once per served request — neither
    // dropped on the open-loop path nor double-counted by fallback
    // re-routing. ED/SF costs are deterministic per model, so the run
    // totals must equal requests x per-request profile exactly.
    let e = Engine::new(&ecore::default_artifacts_dir()).unwrap();
    let n = 5usize;
    let ds = coco::build(n, 17);
    for (router, model) in [
        ("ED", ecore::models::CANNY_MODEL),
        ("SF", ecore::models::FRONTEND_MODEL),
    ] {
        let per = ecore::devices::gateway_spec()
            .profile(&e.meta(model).unwrap());
        // closed loop
        let mut gw = tiny_gateway(&e, router);
        let mut m = RunMetrics::new(router);
        for scene in ds.iter_scenes() {
            gw.handle(&scene.image, scene.gt.len(), &scene.gt, &mut m)
                .unwrap();
        }
        assert_eq!(m.requests, n);
        assert!(
            (m.gateway_energy_mwh - n as f64 * per.energy_mwh).abs()
                < 1e-9,
            "{router}: closed-loop gateway energy {} != {n} x {}",
            m.gateway_energy_mwh,
            per.energy_mwh
        );
        assert!(
            (m.gateway_latency_s - n as f64 * per.latency_s).abs() < 1e-9,
            "{router}: closed-loop gateway latency"
        );
        // open loop (no shedding at this gentle pacing): still exactly
        // once per *served* request
        let mut gw = tiny_gateway(&e, router);
        let report = ecore::workload::openloop::run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Uniform { gap_s: 2.0 },
                queue_capacity: 8,
                seed: 4,
                churn: None,
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        assert_eq!(report.dropped, 0);
        let m = &report.metrics;
        assert!(
            (m.gateway_energy_mwh
                - m.requests as f64 * per.energy_mwh)
                .abs()
                < 1e-9,
            "{router}: open-loop gateway energy"
        );
        assert!(
            (m.gateway_latency_s - m.requests as f64 * per.latency_s)
                .abs()
                < 1e-9,
            "{router}: open-loop gateway latency"
        );
    }
    // count-agnostic and feedback routers pay nothing at the gateway
    for router in ["LE", "OB"] {
        let mut gw = tiny_gateway(&e, router);
        let mut m = RunMetrics::new(router);
        for scene in ds.iter_scenes() {
            gw.handle(&scene.image, scene.gt.len(), &scene.gt, &mut m)
                .unwrap();
        }
        assert_eq!(m.gateway_energy_mwh, 0.0, "{router}");
        assert_eq!(m.gateway_latency_s, 0.0, "{router}");
    }
}

#[test]
fn retried_requests_pay_gateway_cost_exactly_once() {
    // Estimator caching through the churn retry path: a request's
    // estimate + GatewayCost are produced once at first arrival and
    // carried through every retry re-dispatch, so the run's recorded
    // gateway cost is exactly (served requests) x (per-request
    // profile) even when many requests were retried — and the
    // estimator is never re-consulted for a retry.
    use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};

    let e = Engine::new(&ecore::default_artifacts_dir()).unwrap();
    let per = ecore::devices::gateway_spec()
        .profile(&e.meta(ecore::models::CANNY_MODEL).unwrap());
    let ds = coco::build(60, 23);
    let mut gw = tiny_gateway(&e, "ED");
    let report = ecore::workload::openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 300.0 },
            queue_capacity: 4,
            seed: 9,
            churn: Some(ChurnConfig {
                // fast flapping: crashes lose queued work, quick
                // recoveries let retries land again
                mtbf_s: 0.05,
                mttr_s: 0.05,
                probe_interval_s: 0.02,
                probe_timeout_s: 0.01,
                suspect_after: 1,
                warmup_s: 0.05,
                warmup_penalty: 0.5,
                policy: ResiliencePolicy::Retry { budget: 8 },
                retry_backoff_s: 0.02,
                hedge_cancel: false,
                horizon_slack_s: 2.0,
                seed: 11,
            }),
            slo: None,
            adapt: None,
            campaign: None,
            obs: None,
        },
    )
    .unwrap();
    let churn = report.churn.as_ref().expect("churn report");
    assert!(churn.crashes > 0, "scenario must crash nodes");
    assert!(
        churn.retried > 0,
        "scenario must exercise the retry path ({} crashes)",
        churn.crashes
    );
    let m = &report.metrics;
    assert_eq!(
        m.requests + report.dropped + churn.lost,
        report.offered,
        "every request accounted exactly once"
    );
    // the invariant under test: one estimator payment per SERVED
    // request, no matter how many times its copies were re-dispatched
    assert!(
        (m.gateway_energy_mwh - m.requests as f64 * per.energy_mwh)
            .abs()
            < 1e-9,
        "gateway energy {} != {} x {} despite {} retries",
        m.gateway_energy_mwh,
        m.requests,
        per.energy_mwh,
        churn.retried
    );
    assert!(
        (m.gateway_latency_s - m.requests as f64 * per.latency_s).abs()
            < 1e-9,
        "gateway latency must be paid exactly once per served request"
    );
}

#[test]
fn failover_reroutes_when_node_dies() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(12, 5);
    let spec = router_by_name("Orc").unwrap();
    let pool = NodePool::deploy(
        &h.engine,
        &deployed.pairs(),
        &fleet(),
        1,
    )
    .unwrap();
    let mut gw = Gateway::new(&h.engine, spec, deployed.clone(), pool, 5.0, 1);
    // kill the crowded-group favourite
    let favourite = ecore::router::GreedyRouter::new(5.0)
        .route(&deployed, 4)
        .unwrap();
    assert!(gw.pool_mut().set_health(&favourite, false));
    let m = workload::run_dataset(&mut gw, &ds).unwrap();
    assert_eq!(m.requests, 12);
    assert!(gw.fallbacks > 0, "expected fallbacks");
    // the dead pair served nothing
    assert!(!m.per_pair.contains_key(&favourite.to_string()));
}

#[test]
fn all_nodes_down_is_an_error() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let pool =
        NodePool::deploy(&h.engine, &deployed.pairs(), &fleet(), 1).unwrap();
    let mut gw = Gateway::new(
        &h.engine,
        router_by_name("Orc").unwrap(),
        deployed.clone(),
        pool,
        5.0,
        1,
    );
    for p in deployed.pairs() {
        gw.pool_mut().set_health(&p, false);
    }
    let s = ecore::dataset::scene::render_spec(&ecore::dataset::SceneSpec {
        id: 0,
        seed: 1,
        n_objects: 1,
    });
    let mut m = ecore::metrics::RunMetrics::new("t");
    assert!(gw.handle(&s.image, 1, &s.gt, &mut m).is_err());
}

#[test]
fn batch_routing_saves_energy_at_equal_accuracy_shape() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(16, 6);
    let scenes: Vec<_> = ds.iter_scenes().collect();

    // per-request
    let spec = router_by_name("Orc").unwrap();
    let m_req =
        run_router_on_dataset(&h, spec, &deployed, &ds).unwrap();

    // batched (4)
    let pool =
        NodePool::deploy(&h.engine, &deployed.pairs(), &fleet(), 1).unwrap();
    let mut gw = Gateway::new(&h.engine, spec, deployed.clone(), pool, 5.0, 1);
    let mut m_batch = ecore::metrics::RunMetrics::new("batch");
    for chunk in scenes.chunks(4) {
        let images: Vec<_> = chunk
            .iter()
            .map(|s| (s.image.clone(), s.gt.len(), s.gt.clone()))
            .collect();
        gw.handle_batch(&images, &mut m_batch).unwrap();
    }
    assert_eq!(m_batch.requests, 16);
    assert!(
        m_batch.total_energy_mwh() < m_req.total_energy_mwh(),
        "batching should amortize preprocessing: {} vs {}",
        m_batch.total_energy_mwh(),
        m_req.total_energy_mwh()
    );
}

#[test]
fn drifting_pool_costs_more_than_static() {
    let h = harness();
    let deployed = deployed_store(&h).unwrap();
    let ds = coco::build(40, 8);
    let spec = router_by_name("LE").unwrap();

    let m_static =
        run_router_on_dataset(&h, spec, &deployed, &ds).unwrap();

    let pool =
        NodePool::deploy(&h.engine, &deployed.pairs(), &fleet(), 1).unwrap();
    let mut gw = Gateway::new(&h.engine, spec, deployed.clone(), pool, 5.0, 1);
    gw.pool_mut().enable_drift(
        &ecore::devices::drift::DriftConfig {
            heat_per_busy_s: 50.0, // aggressive: throttle quickly
            cool_per_idle_s: 0.0,
            ..Default::default()
        },
        3,
    );
    let m_drift = workload::run_dataset(&mut gw, &ds).unwrap();
    assert!(
        m_drift.total_latency_s > m_static.total_latency_s,
        "drift should slow the run: {} vs {}",
        m_drift.total_latency_s,
        m_static.total_latency_s
    );
}
