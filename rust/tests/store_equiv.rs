//! Equivalence suite for the indexed routing data layer (DESIGN.md
//! §10): the interned/indexed [`ProfileStore`] + [`RoutingView`] must
//! return identical rows, aggregates, and routing winners to a naive
//! reference implementation that replicates the legacy linear-scan
//! code path — on randomized stores with coarse value grids (so exact
//! ties are common), shuffled insertion orders, duplicate
//! (pair, group) rows, and non-finite poison rows.
//!
//! Every comparison is EXACT (`==` on f64 / full row equality): the
//! refactor's contract is bit-identical decisions, not approximate
//! ones.

use ecore::router::{
    GreedyRouter, PairKey, PairProfile, Policy, PolicyKind, ProfileStore,
    RoutingView,
};
use ecore::util::prop::forall_ok;
use ecore::util::rng::Rng;

/// The legacy store: insertion-order rows, linear scans everywhere.
/// Each method is a faithful copy of the pre-refactor implementation.
struct NaiveStore {
    rows: Vec<PairProfile>,
}

impl NaiveStore {
    fn new(rows: &[PairProfile]) -> Self {
        Self {
            rows: rows
                .iter()
                .filter(|r| {
                    r.map.is_finite()
                        && r.latency_s.is_finite()
                        && r.energy_mwh.is_finite()
                })
                .cloned()
                .collect(),
        }
    }

    fn pairs(&self) -> Vec<PairKey> {
        let mut v: Vec<PairKey> =
            self.rows.iter().map(|r| r.pair.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    fn groups(&self) -> Vec<usize> {
        let mut g: Vec<usize> =
            self.rows.iter().map(|r| r.group).collect();
        g.sort();
        g.dedup();
        g
    }

    fn group_rows(&self, group: usize) -> Vec<&PairProfile> {
        self.rows.iter().filter(|r| r.group == group).collect()
    }

    fn lookup(&self, pair: &PairKey, group: usize) -> Option<&PairProfile> {
        self.group_rows(group)
            .into_iter()
            .find(|r| &r.pair == pair)
    }

    fn mean(
        &self,
        pair: &PairKey,
        f: impl Fn(&PairProfile) -> f64,
    ) -> f64 {
        let vals: Vec<f64> = self
            .rows
            .iter()
            .filter(|r| &r.pair == pair)
            .map(f)
            .collect();
        if vals.is_empty() {
            f64::INFINITY
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    fn overall_map(&self, pair: &PairKey) -> f64 {
        let vals: Vec<f64> = self
            .rows
            .iter()
            .filter(|r| &r.pair == pair)
            .map(|r| r.map)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    fn restrict(&self, pairs: &[PairKey]) -> NaiveStore {
        NaiveStore {
            rows: self
                .rows
                .iter()
                .filter(|r| pairs.contains(&r.pair))
                .cloned()
                .collect(),
        }
    }

    /// The legacy Algorithm 1 (filter by mAP margin, min energy,
    /// pair-key tie-break).
    fn greedy(&self, delta: f64, group: usize) -> Option<PairKey> {
        let rows = self.group_rows(group);
        if rows.is_empty() {
            return None;
        }
        let map_max = rows
            .iter()
            .map(|r| r.map)
            .fold(f64::NEG_INFINITY, f64::max);
        let map_min = map_max - delta;
        rows.into_iter()
            .filter(|r| r.map >= map_min)
            .min_by(|a, b| {
                a.energy_mwh
                    .total_cmp(&b.energy_mwh)
                    .then_with(|| a.pair.cmp(&b.pair))
            })
            .map(|r| r.pair.clone())
    }

    /// The legacy static baselines (LE/LI/HM), as `min_by_metric` did.
    fn min_by_metric(
        &self,
        metric: impl Fn(&PairKey) -> f64,
    ) -> Option<PairKey> {
        let pairs = self.pairs();
        pairs
            .iter()
            .min_by(|a, b| {
                metric(a).total_cmp(&metric(b)).then_with(|| a.cmp(b))
            })
            .cloned()
    }

    /// The legacy HMG (group max-mAP, ties toward the lower pair key).
    fn hmg(&self, group: usize) -> Option<PairKey> {
        self.group_rows(group)
            .into_iter()
            .max_by(|a, b| {
                a.map.total_cmp(&b.map).then_with(|| b.pair.cmp(&a.pair))
            })
            .map(|r| r.pair.clone())
    }
}

/// Randomized rows: coarse grids (ties common), shuffled insertion
/// order, duplicate (pair, group) rows, occasional poison rows.
fn random_rows(r: &mut Rng) -> Vec<PairProfile> {
    let n_pairs = 2 + r.below(6) as usize;
    // sparse, unsorted group labels
    let n_groups = 1 + r.below(4) as usize;
    let group_labels: Vec<usize> =
        (0..n_groups).map(|_| r.below(9) as usize).collect();
    let mut rows = Vec::new();
    for p in 0..n_pairs {
        for g in &group_labels {
            rows.push(PairProfile {
                pair: PairKey::new(&format!("m{p}"), "d"),
                group: *g,
                map: (r.below(6) * 20) as f64,
                latency_s: (1 + r.below(4)) as f64 * 0.01,
                energy_mwh: (1 + r.below(4)) as f64 * 0.5,
            });
        }
    }
    // occasional duplicate (pair, group) row with different values
    if r.below(2) == 0 && !rows.is_empty() {
        let i = r.below(rows.len() as u64) as usize;
        let mut dup = rows[i].clone();
        dup.energy_mwh = (1 + r.below(4)) as f64 * 0.5;
        dup.map = (r.below(6) * 20) as f64;
        rows.push(dup);
    }
    // occasional poison row (must be filtered identically)
    if r.below(3) == 0 {
        rows.push(PairProfile {
            pair: PairKey::new("poison", "d"),
            group: group_labels[0],
            map: f64::NAN,
            latency_s: 0.01,
            energy_mwh: 1.0,
        });
    }
    r.shuffle(&mut rows);
    rows
}

/// Serialize rows exactly like `ProfileStore::to_json` does (one
/// object per row, insertion order) — the independent expectation for
/// the restrict/order equivalence check.
fn serialize_rows(rows: &[PairProfile]) -> String {
    use ecore::util::json::Json;
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::str(&r.pair.model)),
                    ("device", Json::str(&r.pair.device)),
                    ("group", Json::num(r.group as f64)),
                    ("map", Json::num(r.map)),
                    ("latency_s", Json::num(r.latency_s)),
                    ("energy_mwh", Json::num(r.energy_mwh)),
                ])
            })
            .collect(),
    )
    .dump()
}

fn rows_equal(a: &PairProfile, b: &PairProfile) -> bool {
    a.pair == b.pair
        && a.group == b.group
        && a.map == b.map
        && a.latency_s == b.latency_s
        && a.energy_mwh == b.energy_mwh
}

#[test]
fn prop_indexed_store_matches_naive_reference() {
    forall_ok(
        0xEC0E_1,
        200,
        |r| random_rows(r),
        |rows| {
            let naive = NaiveStore::new(rows);
            let store = ProfileStore::new(rows.clone());

            if store.pairs() != naive.pairs() {
                return Err("pairs() diverged".into());
            }
            if store.groups() != naive.groups() {
                return Err("groups() diverged".into());
            }
            // group_rows: same rows, same (insertion) order
            for g in naive.groups().into_iter().chain([777]) {
                let a = store.group_rows(g);
                let b = naive.group_rows(g);
                if a.len() != b.len() {
                    return Err(format!("group {g} row count"));
                }
                for (x, y) in a.iter().zip(b) {
                    if !rows_equal(x, y) {
                        return Err(format!("group {g} row order"));
                    }
                }
            }
            // lookup + means for every (pair, group) incl. misses
            for p in naive.pairs() {
                for g in naive.groups().into_iter().chain([777]) {
                    match (store.lookup(&p, g), naive.lookup(&p, g)) {
                        (None, None) => {}
                        (Some(x), Some(y)) if rows_equal(x, y) => {}
                        _ => return Err(format!("lookup({p}, {g})")),
                    }
                }
                if store.overall_map(&p) != naive.overall_map(&p) {
                    return Err(format!("overall_map({p})"));
                }
                let id = store.id_of(&p).expect("pair interned");
                let stats = store.stats_of(id);
                if stats.mean_energy_mwh
                    != naive.mean(&p, |r| r.energy_mwh)
                {
                    return Err(format!("mean energy({p})"));
                }
                if stats.mean_latency_s
                    != naive.mean(&p, |r| r.latency_s)
                {
                    return Err(format!("mean latency({p})"));
                }
            }
            // restrict: same surviving rows, same values, same
            // (insertion) order — compared through the serialized
            // form, which emits insertion order by contract
            let all = naive.pairs();
            let keep: Vec<PairKey> =
                all.iter().step_by(2).cloned().collect();
            let ra = store.restrict(&keep);
            let rb = naive.restrict(&keep);
            if ra.to_json().dump() != serialize_rows(&rb.rows) {
                return Err("restrict rows/order diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_view_routing_matches_naive_policies() {
    forall_ok(
        0xEC0E_2,
        200,
        |r| (random_rows(r), r.below(1 << 30)),
        |(rows, seed)| {
            let naive = NaiveStore::new(rows);
            let store = ProfileStore::new(rows.clone());
            let view = RoutingView::new(&store);
            let groups = naive.groups();
            if groups.is_empty() {
                return Ok(());
            }

            // Algorithm 1 across deltas and groups
            for delta in [0.0, 10.0, 40.0, 200.0] {
                let gr = GreedyRouter::new(delta);
                for &g in &groups {
                    let a = gr
                        .route_view(&view, g)
                        .map(|id| store.key_of(id).clone());
                    let b = naive.greedy(delta, g);
                    if a != b {
                        return Err(format!(
                            "greedy(delta={delta}, g={g}): {a:?} vs {b:?}"
                        ));
                    }
                }
            }

            // static baselines (precomputed stats vs on-the-fly scans)
            let checks: [(PolicyKind, Option<PairKey>); 3] = [
                (
                    PolicyKind::LowestEnergy,
                    naive.min_by_metric(|p| {
                        naive.mean(p, |r| r.energy_mwh)
                    }),
                ),
                (
                    PolicyKind::LowestInference,
                    naive.min_by_metric(|p| {
                        naive.mean(p, |r| r.latency_s)
                    }),
                ),
                (
                    PolicyKind::HighestMap,
                    naive.min_by_metric(|p| -naive.overall_map(p)),
                ),
            ];
            for (kind, want) in checks {
                let mut policy = Policy::new(kind, &store, 5.0, *seed);
                let got = policy.route(&store, groups[0]);
                if got != want {
                    return Err(format!(
                        "{kind:?}: {got:?} vs {want:?}"
                    ));
                }
            }
            // HMG per group
            let mut hmg =
                Policy::new(PolicyKind::HighestMapPerGroup, &store, 5.0, 1);
            for &g in &groups {
                let got = hmg.route(&store, g);
                let want = naive.hmg(g);
                if got != want {
                    return Err(format!("HMG(g={g}): {got:?} vs {want:?}"));
                }
            }

            // RR and Random sequences: same seeds, same pair streams
            let pairs = naive.pairs();
            let mut rr =
                Policy::new(PolicyKind::RoundRobin, &store, 5.0, *seed);
            for k in 0..(2 * pairs.len()) {
                let got = rr.route(&store, groups[0]);
                let want = Some(pairs[k % pairs.len()].clone());
                if got != want {
                    return Err(format!("RR step {k}"));
                }
            }
            let mut rnd =
                Policy::new(PolicyKind::Random, &store, 5.0, *seed);
            let mut reference = Rng::new(*seed ^ 0x9e37_79b9);
            for k in 0..8 {
                let got = rnd.route(&store, groups[0]);
                let want = Some(
                    pairs[reference.below(pairs.len() as u64) as usize]
                        .clone(),
                );
                if got != want {
                    return Err(format!("Random step {k}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_exclusion_matches_naive_restrict_routing() {
    // the gateway fallback walk: excluding pairs on a view must route
    // exactly like the legacy restrict-then-route store copies
    forall_ok(
        0xEC0E_3,
        150,
        |r| random_rows(r),
        |rows| {
            let naive = NaiveStore::new(rows);
            let store = ProfileStore::new(rows.clone());
            let pairs = naive.pairs();
            let groups = naive.groups();
            if pairs.len() < 2 || groups.is_empty() {
                return Ok(());
            }
            // exclude every other pair
            let excluded: Vec<PairKey> =
                pairs.iter().skip(1).step_by(2).cloned().collect();
            let remaining: Vec<PairKey> = pairs
                .iter()
                .filter(|p| !excluded.contains(p))
                .cloned()
                .collect();
            let mut view = RoutingView::new(&store);
            for p in &excluded {
                view.exclude(store.id_of(p).expect("interned"));
            }
            let shrunk = naive.restrict(&remaining);
            for delta in [0.0, 40.0] {
                let gr = GreedyRouter::new(delta);
                for &g in &groups {
                    let a = gr
                        .route_view(&view, g)
                        .map(|id| store.key_of(id).clone());
                    let b = shrunk.greedy(delta, g);
                    if a != b {
                        return Err(format!(
                            "excluded greedy(delta={delta}, g={g})"
                        ));
                    }
                }
            }
            // LE over the excluded view == LE over the restricted copy
            let mut policy = Policy::new(
                PolicyKind::LowestEnergy,
                &store,
                5.0,
                7,
            );
            let got = policy
                .route_view(&view, groups[0])
                .map(|id| store.key_of(id).clone());
            let want = shrunk.min_by_metric(|p| {
                shrunk.mean(p, |r| r.energy_mwh)
            });
            if got != want {
                return Err("excluded LE diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_warmup_overlay_matches_scaled_store_copy() {
    // the lifecycle warm-up path: cost-aging on the view must route
    // exactly like the legacy clone + scale_pair store copy
    forall_ok(
        0xEC0E_4,
        150,
        |r| {
            let rows = random_rows(r);
            let mult = 1.0 + (1 + r.below(8)) as f64 * 0.25;
            (rows, mult)
        },
        |(rows, mult)| {
            let store = ProfileStore::new(rows.clone());
            let pairs = store.pairs();
            if pairs.is_empty() {
                return Ok(());
            }
            // age the first pair, as a warming node would be
            let aged_key = &pairs[0];
            let aged_id = store.id_of(aged_key).expect("interned");
            let mut view = RoutingView::new(&store);
            view.age(aged_id, *mult);

            // the legacy reference: clone + scale_pair on the
            // insertion-order rows (the order the old store kept), so
            // every float reduction replays the legacy sum order
            let mut legacy_rows = rows.clone();
            for lr in
                legacy_rows.iter_mut().filter(|r| &r.pair == aged_key)
            {
                lr.latency_s *= *mult;
                lr.energy_mwh *= *mult;
            }
            let naive = NaiveStore::new(&legacy_rows);

            for delta in [0.0, 40.0] {
                let gr = GreedyRouter::new(delta);
                for g in store.groups() {
                    let a = gr
                        .route_view(&view, g)
                        .map(|id| store.key_of(id).clone());
                    let b = naive.greedy(delta, g);
                    if a != b {
                        return Err(format!(
                            "aged greedy(delta={delta}, g={g}): \
                             {a:?} vs {b:?}"
                        ));
                    }
                }
            }
            // aged means equal the scaled copy's on-the-fly means
            let view_mean = view.mean_energy_mwh(aged_id);
            let naive_mean = naive.mean(aged_key, |r| r.energy_mwh);
            if view_mean != naive_mean {
                return Err(format!(
                    "aged mean energy {view_mean} vs {naive_mean}"
                ));
            }
            // LE/LI over the aged view == over the scaled copy
            for (kind, want) in [
                (
                    PolicyKind::LowestEnergy,
                    naive.min_by_metric(|p| {
                        naive.mean(p, |r| r.energy_mwh)
                    }),
                ),
                (
                    PolicyKind::LowestInference,
                    naive.min_by_metric(|p| {
                        naive.mean(p, |r| r.latency_s)
                    }),
                ),
            ] {
                let mut policy = Policy::new(kind, &store, 5.0, 3);
                let got = policy
                    .route_view(&view, store.groups()[0])
                    .map(|id| store.key_of(id).clone());
                if got != want {
                    return Err(format!(
                        "aged {kind:?}: {got:?} vs {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}
