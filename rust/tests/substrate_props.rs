//! Property tests over the in-tree substrates: JSON round-trips under
//! randomized structured values, profile-store invariants, chart
//! robustness, and config/CLI interactions — the failure-injection side
//! of the "build every substrate" rule.

use ecore::router::{GreedyRouter, PairKey, PairProfile, ProfileStore};
use ecore::util::json::{self, Json};
use ecore::util::prop::forall_ok;
use ecore::util::rng::Rng;

fn random_json(r: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { r.below(4) } else { r.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(r.below(2) == 0),
        2 => {
            // round-trippable numbers: f64 with limited precision
            let x = (r.range(-1e9, 1e9) * 1e3).round() / 1e3;
            Json::Num(x)
        }
        3 => {
            let len = r.below(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = r.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..r.below(5)).map(|_| random_json(r, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..r.below(5))
                .map(|i| {
                    (format!("k{i}"), random_json(r, depth - 1))
                })
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    forall_ok(
        71,
        300,
        |r| random_json(r, 3),
        |v| {
            let text = v.dump();
            let back = json::parse(&text)
                .map_err(|e| format!("reparse failed: {e} for {text}"))?;
            if &back != v {
                return Err(format!("roundtrip mismatch: {text}"));
            }
            // pretty form must parse to the same value too
            let back2 = json::parse(&v.pretty())
                .map_err(|e| format!("pretty reparse: {e}"))?;
            if &back2 != v {
                return Err("pretty mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    forall_ok(
        72,
        500,
        |r| {
            let len = r.below(40) as usize;
            (0..len)
                .map(|_| (r.below(128) as u8) as char)
                .collect::<String>()
        },
        |s| {
            let _ = json::parse(s); // must return, never panic
            Ok(())
        },
    );
}

fn random_store(r: &mut Rng) -> ProfileStore {
    let pairs = 1 + r.below(10) as usize;
    let groups = 1 + r.below(5) as usize;
    let mut rows = Vec::new();
    for p in 0..pairs {
        for g in 0..groups {
            rows.push(PairProfile {
                pair: PairKey::new(&format!("m{p}"), &format!("d{}", p % 3)),
                group: g,
                map: r.range(0.0, 100.0),
                latency_s: r.range(1e-4, 2.0),
                energy_mwh: r.range(1e-4, 1.0),
            });
        }
    }
    ProfileStore::new(rows)
}

#[test]
fn prop_store_roundtrip_and_restrict_invariants() {
    forall_ok(
        73,
        100,
        |r| random_store(r),
        |store| {
            // JSON persistence round-trip preserves every row
            let back = ProfileStore::from_json(&store.to_json())
                .map_err(|e| e.to_string())?;
            if back.rows().len() != store.rows().len() {
                return Err("row count changed".into());
            }
            // restricting to all pairs is identity on the pair set
            let all = store.pairs();
            let same = store.restrict(&all);
            if same.pairs() != all {
                return Err("restrict(all) changed pairs".into());
            }
            // restricting to one pair leaves only its rows
            let one = vec![all[0].clone()];
            let r1 = store.restrict(&one);
            if !r1.rows().iter().all(|row| row.pair == all[0]) {
                return Err("restrict leaked foreign rows".into());
            }
            // group index is consistent
            for g in store.groups() {
                if store.group_rows(g).is_empty() {
                    return Err(format!("indexed group {g} empty"));
                }
            }
            Ok(())
        },
    );
}

// ---- Algorithm 1 edge cases (paper §3.2 / Theorem 3.1) -------------------

/// Check the greedy choice against the brute-force optimum of the
/// constrained problem on one (store, delta, group) instance.
fn check_theorem_31(
    store: &ProfileStore,
    delta: f64,
    group: usize,
) -> Result<(), String> {
    let rows = store.group_rows(group);
    let got = match GreedyRouter::new(delta).route(store, group) {
        Some(p) => p,
        None if rows.is_empty() => return Ok(()),
        None => return Err("no route for a non-empty group".into()),
    };
    let map_max = rows
        .iter()
        .map(|r| r.map)
        .fold(f64::NEG_INFINITY, f64::max);
    let chosen = rows
        .iter()
        .find(|r| r.pair == got)
        .ok_or("chosen pair not in group")?;
    // (i) feasibility: within delta of the group's best mAP
    if chosen.map < map_max - delta - 1e-12 {
        return Err(format!(
            "constraint violated: {} < {map_max} - {delta}",
            chosen.map
        ));
    }
    // (ii) optimality: no feasible row has strictly lower energy
    let brute = rows
        .iter()
        .filter(|r| r.map >= map_max - delta)
        .map(|r| r.energy_mwh)
        .fold(f64::INFINITY, f64::min);
    if chosen.energy_mwh > brute + 1e-12 {
        return Err(format!(
            "not optimal: {} > {brute}",
            chosen.energy_mwh
        ));
    }
    Ok(())
}

#[test]
fn prop_greedy_tie_break_is_row_order_independent() {
    // energies and mAPs drawn from coarse grids so exact ties are
    // common; the routed pair must not depend on row insertion order.
    forall_ok(
        81,
        200,
        |r| {
            let n = 3 + r.below(6) as usize;
            let mut rows = Vec::new();
            for p in 0..n {
                rows.push(PairProfile {
                    pair: PairKey::new(&format!("m{p}"), "d"),
                    group: 0,
                    map: 50.0 + (r.below(5) * 10) as f64,
                    latency_s: 0.01,
                    energy_mwh: (1 + r.below(4)) as f64 * 0.5,
                });
            }
            let mut shuffled = rows.clone();
            r.shuffle(&mut shuffled);
            let delta = (r.below(4) * 10) as f64;
            (rows, shuffled, delta)
        },
        |(rows, shuffled, delta)| {
            let a = GreedyRouter::new(*delta)
                .route(&ProfileStore::new(rows.clone()), 0);
            let b = GreedyRouter::new(*delta)
                .route(&ProfileStore::new(shuffled.clone()), 0);
            if a != b {
                return Err(format!("order-dependent: {a:?} vs {b:?}"));
            }
            if a.is_none() {
                return Err("no route".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_greedy_delta_extremes() {
    // delta = 0: accuracy-first, the choice achieves the group's
    // mAP_max exactly. delta >= mAP_max: the margin constraint is
    // vacuous and the choice is the group's pure energy minimum.
    forall_ok(
        82,
        150,
        |r| random_store(r),
        |store| {
            for g in store.groups() {
                let rows = store.group_rows(g);
                let map_max = rows
                    .iter()
                    .map(|r| r.map)
                    .fold(f64::NEG_INFINITY, f64::max);

                let tight = GreedyRouter::new(0.0)
                    .route(store, g)
                    .ok_or("no route at delta 0")?;
                let chosen = rows
                    .iter()
                    .find(|r| r.pair == tight)
                    .ok_or("delta-0 choice not in group")?;
                if (chosen.map - map_max).abs() > 1e-12 {
                    return Err(format!(
                        "delta 0 chose mAP {} != max {map_max}",
                        chosen.map
                    ));
                }

                let loose = GreedyRouter::new(101.0)
                    .route(store, g)
                    .ok_or("no route at delta 101")?;
                let min_e = rows
                    .iter()
                    .map(|r| r.energy_mwh)
                    .fold(f64::INFINITY, f64::min);
                let got = rows
                    .iter()
                    .find(|r| r.pair == loose)
                    .ok_or("loose choice not in group")?
                    .energy_mwh;
                if (got - min_e).abs() > 1e-12 {
                    return Err(format!(
                        "vacuous delta chose energy {got} != min {min_e}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_theorem_31_holds_on_randomly_perturbed_stores() {
    // Theorem 3.1 re-checked after perturbing every measurement of a
    // random store by ±1% — the equivalence with brute force must be
    // stable under measurement noise, not an artifact of one grid.
    forall_ok(
        83,
        150,
        |r| {
            let base = random_store(r);
            let rows: Vec<PairProfile> = base
                .rows()
                .iter()
                .map(|row| PairProfile {
                    pair: row.pair.clone(),
                    group: row.group,
                    map: (row.map * r.range(0.99, 1.01)).min(100.0),
                    latency_s: row.latency_s * r.range(0.99, 1.01),
                    energy_mwh: row.energy_mwh * r.range(0.99, 1.01),
                })
                .collect();
            let delta = [0.0, 5.0, 25.0][r.below(3) as usize];
            let group = r.below(6) as usize;
            (ProfileStore::new(rows), delta, group)
        },
        |(store, delta, group)| check_theorem_31(store, *delta, *group),
    );
}

#[test]
fn prop_chart_never_panics() {
    forall_ok(
        74,
        100,
        |r| {
            let n = r.below(20) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (r.range(-1e6, 1e6), r.range(-1e6, 1e6)))
                .collect();
            pts
        },
        |pts| {
            let s = ecore::util::chart::line_chart(
                "fuzz",
                &[("s", pts.clone())],
                40,
                10,
            );
            if s.is_empty() {
                return Err("empty chart".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_config_table_parse_stability() {
    // generated key=value files always parse, and parsed numbers survive
    forall_ok(
        75,
        100,
        |r| {
            let n = 1 + r.below(6) as usize;
            let mut text = String::from("[s]\n");
            let mut vals = Vec::new();
            for i in 0..n {
                let v = (r.range(-1e6, 1e6) * 100.0).round() / 100.0;
                text.push_str(&format!("k{i} = {v}\n"));
                vals.push(v);
            }
            (text, vals)
        },
        |(text, vals)| {
            let t = ecore::config::Table::parse(text)
                .map_err(|e| e.to_string())?;
            for (i, v) in vals.iter().enumerate() {
                let got = t.f64_or(&format!("s.k{i}"), f64::NAN);
                if (got - v).abs() > 1e-9 {
                    return Err(format!("k{i}: {got} != {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_percentiles_agree_across_stats_and_metrics() {
    // the batched `percentiles` helper, the single-query `percentile`,
    // and `RunMetrics::latency_percentile` must agree on arbitrary
    // sample sets — the SLO experiment reports p99 through all three
    // paths and they must never diverge.
    use ecore::metrics::RunMetrics;
    use ecore::util::stats::{percentile, percentiles};
    forall_ok(
        77,
        200,
        |r| {
            let n = 1 + r.below(64) as usize;
            (0..n).map(|_| r.range(0.0, 10.0)).collect::<Vec<f64>>()
        },
        |xs| {
            let ps = [0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0];
            let batch = percentiles(xs, &ps);
            let mut m = RunMetrics::new("prop");
            m.latency_samples = xs.clone();
            for (i, &p) in ps.iter().enumerate() {
                let single = percentile(xs, p);
                if batch[i].to_bits() != single.to_bits() {
                    return Err(format!(
                        "p{p}: batch {} != single {single}",
                        batch[i]
                    ));
                }
                if m.latency_percentile(p).to_bits() != single.to_bits() {
                    return Err(format!("p{p}: metrics path diverged"));
                }
            }
            // monotone in p, bounded by the sample extremes
            let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for w in batch.windows(2) {
                if w[0] > w[1] {
                    return Err("percentiles not monotone".into());
                }
            }
            if batch[0] < lo || batch[ps.len() - 1] > hi {
                return Err("percentile outside sample range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn percentile_edge_cases_empty_single_and_all_equal() {
    use ecore::metrics::RunMetrics;
    use ecore::util::stats::{percentile, percentiles};
    // empty: 0.0 by convention, on every path
    let m = RunMetrics::new("empty");
    for p in [0.0, 50.0, 99.0, 100.0] {
        assert_eq!(percentile(&[], p), 0.0);
        assert_eq!(m.latency_percentile(p), 0.0);
    }
    assert_eq!(percentiles(&[], &[50.0, 99.0]), vec![0.0, 0.0]);
    // single sample: every percentile is that sample
    for p in [0.0, 37.5, 50.0, 99.0, 100.0] {
        assert_eq!(percentile(&[4.25], p), 4.25);
    }
    // all-equal samples: every percentile is the common value (the
    // interpolation must not wobble off it)
    let same = vec![0.125; 17];
    for p in [0.0, 10.0, 50.0, 99.0, 100.0] {
        assert_eq!(percentile(&same, p), 0.125);
    }
}

#[test]
fn prop_group_rules_agree_with_store_labels() {
    use ecore::router::GroupRules;
    let rules = GroupRules::paper_default();
    forall_ok(
        76,
        200,
        |r| r.below(50) as usize,
        |&count| {
            let g = rules.group_of(count);
            let expect = if count >= 4 { 4 } else { count };
            if g != expect {
                return Err(format!("count {count} -> group {g}"));
            }
            Ok(())
        },
    );
}
