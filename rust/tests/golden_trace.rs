//! Golden-trace determinism tests: fixed-seed open-loop and fleet runs
//! must serialize their reports bit-identically across two in-process
//! runs, and a golden file pins the serialized trace across commits so
//! silent behavior drift (router, device model, event ordering, JSON
//! substrate) fails loudly.
//!
//! The golden files bootstrap on first run: if
//! `rust/tests/golden/<name>.json` is absent it is written and the test
//! passes (check the file in); afterwards the dump is compared byte for
//! byte. To accept an *intentional* behavior change, delete the golden
//! file, re-run the test, and commit the regenerated file.

use std::path::PathBuf;

use ecore::adapt::AdaptConfig;
use ecore::devices::drift::DriftConfig;
use ecore::fleet::{self, DispatchPolicy, FleetBuilder, FleetConfig};
use ecore::gateway::{router_by_name, Gateway};
use ecore::lifecycle::campaign::CampaignConfig;
use ecore::lifecycle::{ChurnConfig, ResiliencePolicy};
use ecore::nodes::NodePool;
use ecore::obs::ObsConfig;
use ecore::router::{PairKey, PairProfile, ProfileStore};
use ecore::runtime::Engine;
use ecore::workload::openloop::{self, ArrivalProcess, OpenLoopConfig};

fn engine() -> Engine {
    Engine::new(&ecore::default_artifacts_dir()).unwrap()
}

fn base_store() -> ProfileStore {
    let mut rows = Vec::new();
    for g in 0..5 {
        rows.push(PairProfile {
            pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
            group: g,
            map: 50.0,
            latency_s: 0.005,
            energy_mwh: 0.002,
        });
        rows.push(PairProfile {
            pair: PairKey::new("yolov8n", "pi5"),
            group: g,
            map: if g >= 2 { 75.0 } else { 51.0 },
            latency_s: 0.05,
            energy_mwh: 0.05,
        });
    }
    ProfileStore::new(rows)
}

/// One fixed-seed open-loop run (saturating enough to exercise
/// queueing, fallbacks, and shedding), serialized.
fn openloop_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(14, 99);
    let store = base_store();
    let pool =
        NodePool::deploy(e, &store.pairs(), &ecore::devices::fleet(), 3)
            .unwrap();
    let mut gw =
        Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 3);
    let report = openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 60.0 },
            queue_capacity: 4,
            seed: 17,
            churn: None,
            slo: None,
            adapt: None,
            campaign: None,
            obs: None,
        },
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed churn run (aggressive MTBF/MTTR so crashes, probe
/// detections, dispatch failures, retries, and warm-ups all fire within
/// the window), serialized with its churn block.
fn churn_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(16, 43);
    let store = base_store();
    let pool =
        NodePool::deploy(e, &store.pairs(), &ecore::devices::fleet(), 5)
            .unwrap();
    let mut gw =
        Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 5);
    let report = openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 120.0 },
            queue_capacity: 3,
            seed: 23,
            churn: Some(ChurnConfig {
                mtbf_s: 0.15,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                warmup_s: 0.1,
                warmup_penalty: 0.5,
                policy: ResiliencePolicy::Retry { budget: 3 },
                retry_backoff_s: 0.04,
                hedge_cancel: false,
                horizon_slack_s: 1.5,
                seed: 29,
            }),
            slo: None,
            adapt: None,
            campaign: None,
            obs: None,
        },
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed fleet churn run (2 shards, per-shard membership),
/// serialized with its churn block.
fn fleet_churn_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(16, 77);
    let mut fl = FleetBuilder::new(e, base_store())
        .build(
            router_by_name("LE").unwrap(),
            5.0,
            &FleetConfig {
                n_nodes: 6,
                n_shards: 2,
                perturb: 0.1,
                queue_capacity: 2,
                dispatch: DispatchPolicy::LeastLoaded,
                n_sources: 4,
                seed: 31,
                drift: None,
                churn: Some(ChurnConfig {
                    mtbf_s: 0.1,
                    mttr_s: 0.15,
                    probe_interval_s: 0.04,
                    probe_timeout_s: 0.02,
                    suspect_after: 1,
                    warmup_s: 0.1,
                    warmup_penalty: 0.5,
                    policy: ResiliencePolicy::Hedge,
                    retry_backoff_s: 0.04,
                    hedge_cancel: false,
                    horizon_slack_s: 1.0,
                    seed: 37,
                }),
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
                threads: 1,
            },
        )
        .unwrap();
    let report = fleet::run_dataset(
        &mut fl,
        &ds,
        &ArrivalProcess::Poisson { rate_rps: 200.0 },
        31,
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed fleet run (12 perturbed nodes over 3 shards, tight
/// queues so cross-shard fallback fires), serialized.
fn fleet_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(14, 55);
    let mut fl = FleetBuilder::new(e, base_store())
        .build(
            router_by_name("OB").unwrap(),
            5.0,
            &FleetConfig {
                n_nodes: 12,
                n_shards: 3,
                perturb: 0.2,
                queue_capacity: 2,
                dispatch: DispatchPolicy::LeastLoaded,
                n_sources: 4,
                seed: 9,
                drift: None,
                churn: None,
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
                threads: 1,
            },
        )
        .unwrap();
    let report = fleet::run_dataset(
        &mut fl,
        &ds,
        &ArrivalProcess::Poisson { rate_rps: 120.0 },
        9,
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed SLO run (three deadline classes, admission control,
/// EDF ordering, and dynamic batching all active at a saturating rate),
/// serialized with its slo block.
fn slo_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(20, 61);
    let store = base_store();
    let pool =
        NodePool::deploy(e, &store.pairs(), &ecore::devices::fleet(), 4)
            .unwrap();
    let mut gw =
        Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 4);
    let report = openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 180.0 },
            queue_capacity: 4,
            seed: 41,
            churn: None,
            slo: Some(ecore::workload::slo::SloConfig::default()),
            adapt: None,
            campaign: None,
            obs: None,
        },
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed fleet SLO run (2 shards, batching + admission on the
/// shared heap), serialized with its slo block.
fn fleet_slo_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(18, 83);
    let mut fl = FleetBuilder::new(e, base_store())
        .build(
            router_by_name("LE").unwrap(),
            5.0,
            &FleetConfig {
                n_nodes: 6,
                n_shards: 2,
                perturb: 0.1,
                queue_capacity: 4,
                dispatch: DispatchPolicy::LeastLoaded,
                n_sources: 4,
                seed: 47,
                drift: None,
                churn: None,
                slo: Some(ecore::workload::slo::SloConfig::default()),
                adapt: None,
                campaign: None,
                obs: None,
                threads: 1,
            },
        )
        .unwrap();
    let report = fleet::run_dataset(
        &mut fl,
        &ds,
        &ArrivalProcess::Poisson { rate_rps: 220.0 },
        47,
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed adaptation run (drifting fleet, telemetry feedback
/// and the energy-proportional scaler both active at a rate with real
/// troughs), serialized with its adapt block.
fn adapt_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(18, 91);
    let store = base_store();
    let pool =
        NodePool::deploy(e, &store.pairs(), &ecore::devices::fleet(), 4)
            .unwrap();
    let mut gw =
        Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 4);
    gw.pool_mut().enable_drift(&DriftConfig::default(), 13);
    let report = openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 150.0 },
            queue_capacity: 4,
            seed: 53,
            churn: None,
            slo: None,
            adapt: Some(AdaptConfig {
                scale_interval_s: 0.05,
                ..Default::default()
            }),
            campaign: None,
            obs: None,
        },
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed fleet adaptation run (2 shards, per-shard scalers on
/// a drifting fleet, reports merged), serialized with its adapt block.
fn fleet_adapt_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(16, 67);
    let mut fl = FleetBuilder::new(e, base_store())
        .build(
            router_by_name("LE").unwrap(),
            5.0,
            &FleetConfig {
                n_nodes: 6,
                n_shards: 2,
                perturb: 0.1,
                queue_capacity: 4,
                dispatch: DispatchPolicy::LeastLoaded,
                n_sources: 4,
                seed: 59,
                drift: Some(DriftConfig::default()),
                churn: None,
                slo: None,
                adapt: Some(AdaptConfig {
                    scale_interval_s: 0.05,
                    ..Default::default()
                }),
                campaign: None,
                obs: None,
                threads: 1,
            },
        )
        .unwrap();
    let report = fleet::run_dataset(
        &mut fl,
        &ds,
        &ArrivalProcess::Poisson { rate_rps: 200.0 },
        59,
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed open-loop campaign run (domain-wide outages layered
/// on quiet per-node churn; gateway kills disabled — the open loop has
/// no shards), serialized with its campaign block.
fn campaign_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(16, 47);
    let store = base_store();
    let pool =
        NodePool::deploy(e, &store.pairs(), &ecore::devices::fleet(), 5)
            .unwrap();
    let mut gw =
        Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 5);
    let report = openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 120.0 },
            queue_capacity: 3,
            seed: 67,
            churn: Some(ChurnConfig {
                mtbf_s: f64::INFINITY,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                warmup_s: 0.1,
                warmup_penalty: 0.5,
                policy: ResiliencePolicy::Retry { budget: 3 },
                retry_backoff_s: 0.04,
                hedge_cancel: false,
                horizon_slack_s: 1.5,
                seed: 71,
            }),
            slo: None,
            adapt: None,
            campaign: Some(CampaignConfig {
                domain_size: 2,
                domain_mtbf_s: 0.15,
                domain_mttr_s: 0.12,
                gateway_mtbf_s: f64::INFINITY,
                gateway_mttr_s: 0.1,
                seed: 73,
            }),
            obs: None,
        },
    )
    .unwrap();
    report.to_json().pretty()
}

/// One fixed-seed fleet campaign run (domain outages + gateway kills
/// with deterministic re-homing over 3 shards), serialized with its
/// campaign block.
fn fleet_campaign_dump(e: &Engine) -> String {
    let ds = ecore::dataset::coco::build(16, 53);
    let mut fl = FleetBuilder::new(e, base_store())
        .build(
            router_by_name("LE").unwrap(),
            5.0,
            &FleetConfig {
                n_nodes: 9,
                n_shards: 3,
                perturb: 0.1,
                queue_capacity: 2,
                dispatch: DispatchPolicy::LeastLoaded,
                n_sources: 4,
                seed: 79,
                drift: None,
                churn: Some(ChurnConfig {
                    mtbf_s: 0.2,
                    mttr_s: 0.15,
                    probe_interval_s: 0.04,
                    probe_timeout_s: 0.02,
                    suspect_after: 1,
                    warmup_s: 0.1,
                    warmup_penalty: 0.5,
                    policy: ResiliencePolicy::Retry { budget: 3 },
                    retry_backoff_s: 0.04,
                    hedge_cancel: false,
                    horizon_slack_s: 1.0,
                    seed: 83,
                }),
                slo: None,
                adapt: None,
                campaign: Some(CampaignConfig {
                    domain_size: 3,
                    domain_mtbf_s: 0.3,
                    domain_mttr_s: 0.12,
                    gateway_mtbf_s: 0.25,
                    gateway_mttr_s: 0.12,
                    seed: 89,
                }),
                obs: None,
                threads: 1,
            },
        )
        .unwrap();
    let report = fleet::run_dataset(
        &mut fl,
        &ds,
        &ArrivalProcess::Poisson { rate_rps: 200.0 },
        79,
    )
    .unwrap();
    report.to_json().pretty()
}

#[test]
fn open_loop_report_serializes_bit_identically_across_runs() {
    let e = engine();
    assert_eq!(openloop_dump(&e), openloop_dump(&e));
}

#[test]
fn fleet_report_serializes_bit_identically_across_runs() {
    let e = engine();
    assert_eq!(fleet_dump(&e), fleet_dump(&e));
}

#[test]
fn churn_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = churn_dump(&e);
    assert_eq!(a, churn_dump(&e));
    // the block only serializes when churn ran
    assert!(a.contains("\"churn\""));
    assert!(a.contains("\"crashes\""));
}

#[test]
fn fleet_churn_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = fleet_churn_dump(&e);
    assert_eq!(a, fleet_churn_dump(&e));
    assert!(a.contains("\"churn\""));
}

#[test]
fn slo_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = slo_dump(&e);
    assert_eq!(a, slo_dump(&e));
    // the block only serializes when SLOs ran
    assert!(a.contains("\"slo\""));
    assert!(a.contains("\"attainment_pct\""));
}

#[test]
fn fleet_slo_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = fleet_slo_dump(&e);
    assert_eq!(a, fleet_slo_dump(&e));
    assert!(a.contains("\"slo\""));
}

/// The whole point of option-gating: an SLO config of `None` adds zero
/// events and zero report keys, so the no-SLO dumps must keep the exact
/// pre-SLO JSON shape (the pinned goldens check the bytes; this checks
/// the shape contract explicitly).
#[test]
fn none_slo_config_leaves_pre_slo_traces_untouched() {
    let e = engine();
    assert!(!openloop_dump(&e).contains("\"slo\""));
    assert!(!fleet_dump(&e).contains("\"slo\""));
    assert!(!churn_dump(&e).contains("\"slo\""));
}

#[test]
fn adapt_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = adapt_dump(&e);
    assert_eq!(a, adapt_dump(&e));
    // the block only serializes when adaptation ran
    assert!(a.contains("\"adapt\""));
    assert!(a.contains("\"telemetry_samples\""));
}

#[test]
fn fleet_adapt_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = fleet_adapt_dump(&e);
    assert_eq!(a, fleet_adapt_dump(&e));
    assert!(a.contains("\"adapt\""));
}

/// Same shape contract for adaptation: `adapt: None` schedules zero
/// scale ticks and adds zero report keys, so every pre-adapt dump —
/// and therefore every pinned golden above — keeps its exact bytes.
#[test]
fn none_adapt_config_leaves_existing_traces_untouched() {
    let e = engine();
    assert!(!openloop_dump(&e).contains("\"adapt\""));
    assert!(!fleet_dump(&e).contains("\"adapt\""));
    assert!(!churn_dump(&e).contains("\"adapt\""));
    assert!(!slo_dump(&e).contains("\"adapt\""));
}

#[test]
fn campaign_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = campaign_dump(&e);
    assert_eq!(a, campaign_dump(&e));
    // the block only serializes when a campaign ran
    assert!(a.contains("\"campaign\""));
    assert!(a.contains("\"domain_outages\""));
}

#[test]
fn fleet_campaign_report_serializes_bit_identically_across_runs() {
    let e = engine();
    let a = fleet_campaign_dump(&e);
    assert_eq!(a, fleet_campaign_dump(&e));
    assert!(a.contains("\"campaign\""));
    assert!(a.contains("\"gw_kills\""));
}

/// Same shape contract for campaigns: `campaign: None` injects zero
/// plan events and adds zero report keys, so every pre-campaign dump —
/// and therefore every pinned golden above — keeps its exact bytes.
#[test]
fn none_campaign_config_leaves_existing_traces_untouched() {
    let e = engine();
    assert!(!openloop_dump(&e).contains("\"campaign\""));
    assert!(!fleet_dump(&e).contains("\"campaign\""));
    assert!(!churn_dump(&e).contains("\"campaign\""));
    assert!(!fleet_churn_dump(&e).contains("\"campaign\""));
}

fn check_golden(name: &str, dump: &str) {
    check_golden_file(&format!("{name}.json"), dump);
}

/// Like [`check_golden`] but takes the golden file name verbatim, for
/// non-`.json` artifacts (the obs layer exports `.jsonl`).
fn check_golden_file(file: &str, dump: &str) {
    let dir =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(file);
    if path.exists() {
        let golden = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            golden,
            dump,
            "{file}: trace drifted from the checked-in golden at {}. \
             If the behavior change is intentional, delete the file, \
             re-run, and commit the regenerated golden.",
            path.display()
        );
    } else {
        std::fs::write(&path, dump).unwrap();
        eprintln!("[golden] bootstrapped {}", path.display());
    }
}

#[test]
fn golden_openloop_trace_is_pinned() {
    let e = engine();
    check_golden("openloop_trace", &openloop_dump(&e));
}

#[test]
fn golden_fleet_trace_is_pinned() {
    let e = engine();
    check_golden("fleet_trace", &fleet_dump(&e));
}

#[test]
fn golden_churn_trace_is_pinned() {
    let e = engine();
    check_golden("churn_trace", &churn_dump(&e));
}

#[test]
fn golden_fleet_churn_trace_is_pinned() {
    let e = engine();
    check_golden("fleet_churn_trace", &fleet_churn_dump(&e));
}

#[test]
fn golden_slo_trace_is_pinned() {
    let e = engine();
    check_golden("slo_trace", &slo_dump(&e));
}

#[test]
fn golden_fleet_slo_trace_is_pinned() {
    let e = engine();
    check_golden("fleet_slo_trace", &fleet_slo_dump(&e));
}

#[test]
fn golden_adapt_trace_is_pinned() {
    let e = engine();
    check_golden("adapt_trace", &adapt_dump(&e));
}

#[test]
fn golden_fleet_adapt_trace_is_pinned() {
    let e = engine();
    check_golden("fleet_adapt_trace", &fleet_adapt_dump(&e));
}

#[test]
fn golden_campaign_trace_is_pinned() {
    let e = engine();
    check_golden("campaign_trace", &campaign_dump(&e));
}

#[test]
fn golden_fleet_campaign_trace_is_pinned() {
    let e = engine();
    check_golden("fleet_campaign_trace", &fleet_campaign_dump(&e));
}

/// One fixed-seed churn + SLO open-loop run with the obs layer on,
/// exported to a scratch dir; returns the `spans.jsonl` and
/// `series.jsonl` bytes. Small head/tail/sample keep the pinned
/// goldens compact while still retaining head, tail, and sampled
/// middle spans.
fn obs_export_dump(e: &Engine) -> (String, String) {
    let dir = std::env::temp_dir()
        .join(format!("ecore_obs_golden_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = ecore::dataset::coco::build(16, 43);
    let store = base_store();
    let pool =
        NodePool::deploy(e, &store.pairs(), &ecore::devices::fleet(), 5)
            .unwrap();
    let mut gw =
        Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 5);
    openloop::run_dataset(
        &mut gw,
        &ds,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps: 120.0 },
            queue_capacity: 3,
            seed: 23,
            churn: Some(ChurnConfig {
                mtbf_s: 0.15,
                mttr_s: 0.2,
                probe_interval_s: 0.05,
                probe_timeout_s: 0.02,
                suspect_after: 1,
                warmup_s: 0.1,
                warmup_penalty: 0.5,
                policy: ResiliencePolicy::Retry { budget: 3 },
                retry_backoff_s: 0.04,
                hedge_cancel: false,
                horizon_slack_s: 1.5,
                seed: 29,
            }),
            slo: Some(ecore::workload::slo::SloConfig::default()),
            adapt: None,
            campaign: None,
            obs: Some(ObsConfig {
                tick_s: 0.1,
                span_head: 4,
                span_tail: 4,
                span_sample: 8,
                seed: 7,
                out_dir: dir.to_string_lossy().into_owned(),
            }),
        },
    )
    .unwrap();
    let spans =
        std::fs::read_to_string(dir.join("spans.jsonl")).unwrap();
    let series =
        std::fs::read_to_string(dir.join("series.jsonl")).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    (spans, series)
}

#[test]
fn golden_obs_spans_and_series_are_pinned() {
    let e = engine();
    let (spans, series) = obs_export_dump(&e);
    assert!(!spans.is_empty() && !series.is_empty());
    check_golden_file("obs_spans.jsonl", &spans);
    check_golden_file("obs_series.jsonl", &series);
}
