"""L2 model tests: variant registry invariants, pyramid correctness,
detector output shapes, end-to-end detection of planted objects, Canny
pipeline behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import scenegen
from compile.kernels import ref


def test_variant_registry_complete():
    # the 8 paper models + yolov8x pseudo-GT generator
    assert len(M.VARIANTS) == 9
    for v in M.VARIANTS.values():
        assert M.NATIVE_RES % v.res == 0
        assert v.k >= 3
        assert 0 < v.sigma0 < v.sigma_max
        assert v.threshold > 0


def test_sigma_ladder_geometric_and_bounded():
    for v in M.VARIANTS.values():
        s = M.pyramid_sigmas(v)
        assert len(s) == v.k + 1
        assert abs(s[0] - v.sigma0) < 1e-9
        assert abs(s[-1] - v.sigma_max) < 1e-6
        ratios = [s[i + 1] / s[i] for i in range(v.k)]
        assert all(abs(r - ratios[0]) < 1e-9 for r in ratios)
        # coarsest blur stays within the taps-truncation comfort zone
        assert v.sigma_max <= 30.0 + 1e-9


def test_band_radii_increasing_and_cover_target_range():
    for v in M.VARIANTS.values():
        radii = M.band_radii_native(v)
        assert all(b > a for a, b in zip(radii, radii[1:]))
        # every variant must cover the sparse-scene radius range [16, 32]
        assert radii[0] <= 16.0
        assert radii[-1] >= 32.0


def test_incremental_sigmas_compose():
    for v in M.VARIANTS.values():
        inc = M.incremental_sigmas(v)
        acc = 0.0
        absolute = M.pyramid_sigmas(v)
        for i, d in enumerate(inc):
            acc = (acc**2 + d**2) ** 0.5
            assert abs(acc - absolute[i]) < 1e-6


def test_pyramid_matches_incremental_ref():
    v = M.VARIANTS["ssd_v1"]
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.random((v.res, v.res), dtype=np.float32))
    pyr = M.make_pyramid(img, v)
    inc = M.incremental_sigmas(v)
    level = ref.blur2d_ref(img, inc[0])
    np.testing.assert_allclose(pyr[0], level, atol=1e-5)
    for i, d in enumerate(inc[1:], start=1):
        level = ref.blur2d_ref(level, d)
        np.testing.assert_allclose(pyr[i], level, atol=1e-5)


@pytest.mark.parametrize("name", list(M.VARIANTS))
def test_detector_output_shape(name):
    v = M.VARIANTS[name]
    fn = jax.jit(M.make_detector(name))
    img = jnp.zeros((M.NATIVE_RES, M.NATIVE_RES), jnp.float32)
    out = fn(img)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (2, v.k, v.res, v.res)


def test_detector_finds_planted_blob():
    # a single high-contrast blob must produce a dominant peak near its
    # centre, at every capacity level
    for name in ("ssd_v1", "yolov8m"):
        v = M.VARIANTS[name]
        img = np.full((384, 384), 0.5, np.float32)
        yy, xx = np.mgrid[0:384, 0:384].astype(np.float32)
        s = 20.0 / 2
        img += 0.5 * np.exp(
            -0.5 * (((xx - 150) / s) ** 2 + ((yy - 220) / s) ** 2)
        ).astype(np.float32)
        heat = np.asarray(jax.jit(M.make_detector(name))(img)[0])
        c, b, y, x = np.unravel_index(np.argmax(heat), heat.shape)
        assert c == 0  # bright
        assert heat[c, b, y, x] > v.threshold
        assert abs(y * v.factor - 220) <= 2 * v.factor
        assert abs(x * v.factor - 150) <= 2 * v.factor


def test_detector_dark_blob_lands_in_class1():
    img = np.full((384, 384), 0.6, np.float32)
    yy, xx = np.mgrid[0:384, 0:384].astype(np.float32)
    img -= 0.5 * np.exp(
        -0.5 * (((xx - 192) / 9) ** 2 + ((yy - 192) / 9) ** 2)
    ).astype(np.float32)
    heat = np.asarray(jax.jit(M.make_detector("yolov8n"))(img)[0])
    c, *_ = np.unravel_index(np.argmax(heat), heat.shape)
    assert c == 1


def test_capacity_gradient_on_crowded_scene():
    """The paper's core phenomenon: high-capacity models respond above
    threshold to small objects that low-capacity models miss."""
    img, objs = scenegen.make_scene(8, seed=42)
    assert len(objs) >= 6
    strong = np.asarray(jax.jit(M.make_detector("yolov8m"))(img)[0])
    weak = np.asarray(jax.jit(M.make_detector("ssd_v1"))(img)[0])
    n_strong = int(
        (strong > M.VARIANTS["yolov8m"].threshold).sum()
    )
    n_weak = int((weak > M.VARIANTS["ssd_v1"].threshold).sum())
    assert n_strong > n_weak


def test_canny_output_shape_and_classes():
    fn = jax.jit(M.make_canny())
    img, _ = scenegen.make_scene(3, seed=1)
    out = np.asarray(fn(img)[0])
    assert out.shape == (M.CANNY_RES, M.CANNY_RES)
    assert set(np.unique(out)).issubset({0.0, 1.0, 2.0})


def test_canny_rings_scale_with_object_count():
    fn = jax.jit(M.make_canny())
    img1, o1 = scenegen.make_scene(1, seed=5)
    img6, o6 = scenegen.make_scene(6, seed=5)
    e1 = float((np.asarray(fn(img1)[0]) == 2.0).sum())
    e6 = float((np.asarray(fn(img6)[0]) == 2.0).sum())
    assert len(o6) > len(o1)
    assert e6 > e1  # more objects -> more strong edge pixels


def test_flops_monotone_with_capacity():
    order = [
        "ssd_v1",
        "ssd_lite",
        "effdet_lite0",
        "effdet_lite1",
        "effdet_lite2",
        "yolov8n",
        "yolov8s",
        "yolov8m",
        "yolov8x",
    ]
    flops = [M.detector_flops(n) for n in order]
    assert all(b > a for a, b in zip(flops, flops[1:]))
    assert M.canny_flops() < flops[0]  # ED estimator cheaper than any model
