"""Manifest contract tests: the JSON handed to the Rust runtime must be
complete and internally consistent."""

import json

from compile import aot, model as M


def test_manifest_structure():
    m = aot.build_manifest()
    assert m["version"] == aot.MANIFEST_VERSION
    assert m["native_res"] == M.NATIVE_RES
    models = m["models"]
    # 9 detectors + ssd_front alias + canny
    assert len(models) == len(M.VARIANTS) + len(M.GATEWAY_MODELS) + 1
    for name, v in M.VARIANTS.items():
        e = models[name]
        assert e["kind"] == "detector"
        assert e["file"] == f"{name}.hlo.txt"
        assert e["input"]["shape"] == [M.NATIVE_RES, M.NATIVE_RES]
        assert e["output"]["shape"] == [2, v.k, v.res, v.res]
        assert e["params"]["threshold"] == v.threshold
        assert len(e["params"]["band_radii_native"]) == v.k
        assert len(e["params"]["sigmas"]) == v.k + 1
        assert e["flops"] > 0


def test_manifest_gateway_models_mirror_base():
    m = aot.build_manifest()["models"]
    for alias, base in M.GATEWAY_MODELS.items():
        assert m[alias]["kind"] == "gateway_detector"
        assert m[alias]["file"] == f"{alias}.hlo.txt"
        assert m[alias]["params"] == m[base]["params"]
        assert m[alias]["flops"] == m[base]["flops"]


def test_manifest_canny_entry():
    e = aot.build_manifest()["models"]["canny"]
    assert e["kind"] == "canny"
    assert e["output"]["shape"] == [M.CANNY_RES, M.CANNY_RES]
    p = e["params"]
    assert p["lo"] < p["hi"]
    assert p["factor"] * p["res"] == M.NATIVE_RES


def test_manifest_is_json_serializable():
    s = json.dumps(aot.build_manifest())
    round_tripped = json.loads(s)
    assert round_tripped["native_res"] == M.NATIVE_RES


def test_fingerprint_stable():
    assert aot._inputs_fingerprint() == aot._inputs_fingerprint()
