"""Scene-generator tests (Python twin of rust/src/dataset/scene.rs)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import scenegen

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(n=st.integers(0, 12), seed=st.integers(0, 10_000))
def test_scene_bounds_and_shape(n, seed):
    img, objs = scenegen.make_scene(n, seed)
    assert img.shape == (scenegen.NATIVE_RES, scenegen.NATIVE_RES)
    assert img.dtype == np.float32
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0
    assert len(objs) <= n


@given(n=st.integers(1, 10), seed=st.integers(0, 10_000))
def test_objects_within_frame_and_separated(n, seed):
    _, objs = scenegen.make_scene(n, seed)
    for o in objs:
        x0, y0, x1, y1 = o.box
        assert 0 <= x0 < x1 <= scenegen.NATIVE_RES
        assert 0 <= y0 < y1 <= scenegen.NATIVE_RES
    for i, a in enumerate(objs):
        for b in objs[i + 1 :]:
            assert not scenegen._boxes_overlap(a.box, b.box, slack=0.0)


def test_radius_law_monotone():
    prev_hi = float("inf")
    for n in range(1, 15):
        lo, hi = scenegen.radius_range(n)
        assert lo <= hi
        assert hi <= prev_hi
        prev_hi = hi
    assert scenegen.radius_range(1)[1] == 32.0
    assert scenegen.radius_range(12)[0] >= 5.0


def test_determinism_by_seed():
    a, oa = scenegen.make_scene(4, 123)
    b, ob = scenegen.make_scene(4, 123)
    np.testing.assert_array_equal(a, b)
    assert [o.box for o in oa] == [o.box for o in ob]
    c, _ = scenegen.make_scene(4, 124)
    assert not np.array_equal(a, c)


def test_contrast_and_classes_present():
    _, objs = scenegen.make_scene(10, 7)
    classes = {o.cls for o in objs}
    assert classes.issubset({0, 1})
    for o in objs:
        lo, hi = scenegen.CONTRAST_RANGE
        assert lo <= o.contrast <= hi
