"""AOT pipeline tests: lowering produces loadable HLO text with the
shapes the manifest promises (the build half of the interchange contract;
the Rust runtime tests exercise the load half against artifacts/)."""

import jax
import jax.numpy as jnp

from compile import aot, model as M


def test_lowered_hlo_text_structure():
    low = aot.lower_fn(M.make_detector("ssd_v1"), (384, 384))
    text = aot.to_hlo_text(low)
    # HLO text module with an entry computation
    assert "HloModule" in text
    assert "ENTRY" in text
    # input parameter and tuple root carry the manifest shapes (HLO text
    # annotates layouts, hence the {…} suffixes)
    assert "f32[384,384]{1,0} parameter(0)" in text
    v = M.VARIANTS["ssd_v1"]
    heat = f"f32[2,{v.k},{v.res},{v.res}]"
    # 1-tuple return convention (the rust loader calls to_tuple1)
    assert f"ROOT" in text
    assert f"({heat}{{3,2,1,0}}) tuple(" in text


def test_canny_lowering_shapes():
    text = aot.to_hlo_text(aot.lower_fn(M.make_canny(), (384, 384)))
    assert f"f32[{M.CANNY_RES},{M.CANNY_RES}]" in text.replace("{1,0}", "")


def test_lowering_is_deterministic():
    f = M.make_detector("ssd_lite")
    a = aot.to_hlo_text(aot.lower_fn(f, (384, 384)))
    b = aot.to_hlo_text(aot.lower_fn(f, (384, 384)))
    assert a == b


def test_detector_jit_matches_unjitted():
    import numpy as np

    rng = np.random.default_rng(3)
    img = jnp.asarray(rng.random((384, 384), dtype=np.float32))
    fn = M.make_detector("ssd_v1")
    eager = fn(img)[0]
    jitted = jax.jit(fn)(img)[0]
    np.testing.assert_allclose(eager, jitted, atol=1e-5)
