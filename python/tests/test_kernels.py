"""L1 kernel-vs-oracle tests: the core correctness signal.

Hypothesis sweeps shapes/parameters; every Pallas kernel must match its
pure-jnp reference to float32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.blur import blur1d, blur2d
from compile.kernels.dog import dog_localmax
from compile.kernels.sobel import sobel_nms

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _img(h, w, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((h, w), dtype=np.float32))


# --- blur ---------------------------------------------------------------


@given(
    h=st.sampled_from([8, 17, 32, 61, 96]),
    w=st.sampled_from([8, 23, 64, 96]),
    sigma=st.floats(0.6, 12.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_blur2d_matches_ref(h, w, sigma, seed):
    img = _img(h, w, seed)
    got = blur2d(img, sigma)
    want = ref.blur2d_ref(img, sigma)
    np.testing.assert_allclose(got, want, atol=1e-5)


@given(
    axis=st.sampled_from([0, 1]),
    sigma=st.floats(0.5, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_blur1d_matches_ref_single_axis(axis, sigma, seed):
    img = _img(48, 48, seed)
    got = blur1d(img, sigma, axis=axis)
    taps = ref.gaussian_taps(sigma)
    want = ref._conv1d_ref(img, taps, axis=axis)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_blur_preserves_constant_image():
    img = jnp.full((32, 32), 0.7, jnp.float32)
    out = blur2d(img, 3.0)
    np.testing.assert_allclose(out, img, atol=1e-5)


def test_blur_mass_preserved_interior():
    # normalized taps: the mean over the full image is preserved up to
    # edge-padding effects; with a constant border it is exact.
    img = _img(64, 64, 3)
    out = blur2d(img, 2.0)
    assert abs(float(out.mean()) - float(img.mean())) < 1e-3


def test_gaussian_taps_normalized_and_symmetric():
    for sigma in (0.5, 1.7, 8.0, 40.0):
        t = ref.gaussian_taps(sigma)
        assert abs(t.sum() - 1.0) < 1e-6
        np.testing.assert_allclose(t, t[::-1])
        assert len(t) % 2 == 1


def test_gaussian_taps_radius_cap():
    assert len(ref.gaussian_taps(100.0)) == 2 * 64 + 1


# --- dog_localmax -------------------------------------------------------


@given(
    k=st.integers(1, 5),
    h=st.sampled_from([8, 24, 48]),
    w=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dog_localmax_matches_ref(k, h, w, seed):
    rng = np.random.default_rng(seed)
    pyr = jnp.asarray(rng.random((k + 1, h, w), dtype=np.float32))
    got = dog_localmax(pyr)
    want = ref.dog_localmax_ref(pyr)
    assert got.shape == (2, k, h, w)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_dog_localmax_peaks_are_sparse_local_maxima():
    rng = np.random.default_rng(7)
    pyr = jnp.asarray(rng.random((3, 32, 32), dtype=np.float32))
    heat = np.asarray(dog_localmax(pyr))
    # every nonzero entry must be >= its 3x3 neighbourhood in the
    # corresponding response map
    d = np.asarray(pyr)[:-1] - np.asarray(pyr)[1:]
    for cls in range(2):
        r = np.maximum(d if cls == 0 else -d, 0.0)
        for s in range(2):
            ys, xs = np.nonzero(heat[cls, s])
            for y, x in zip(ys, xs):
                y0, y1 = max(0, y - 1), min(32, y + 2)
                x0, x1 = max(0, x - 1), min(32, x + 2)
                assert heat[cls, s, y, x] >= r[s, y0:y1, x0:x1].max() - 1e-6


def test_dog_localmax_constant_pyramid_is_silent():
    pyr = jnp.ones((4, 16, 16), jnp.float32)
    assert float(jnp.abs(dog_localmax(pyr)).max()) == 0.0


# --- sobel_nms ----------------------------------------------------------


@given(
    h=st.sampled_from([8, 33, 64]),
    w=st.sampled_from([8, 48]),
    lo=st.floats(0.02, 0.2),
    hi_delta=st.floats(0.01, 0.3),
    seed=st.integers(0, 2**31 - 1),
)
def test_sobel_nms_matches_ref(h, w, lo, hi_delta, seed):
    img = _img(h, w, seed)
    hi = lo + hi_delta
    got = sobel_nms(img, lo, hi)
    want = ref.sobel_nms_ref(img, lo, hi)
    np.testing.assert_allclose(got, want, atol=0)


def test_sobel_nms_output_values_are_classes():
    img = _img(32, 32, 11)
    out = np.asarray(sobel_nms(img, 0.05, 0.15))
    assert set(np.unique(out)).issubset({0.0, 1.0, 2.0})


def test_sobel_nms_flat_image_no_edges():
    img = jnp.full((24, 24), 0.4, jnp.float32)
    assert float(sobel_nms(img, 0.05, 0.15).max()) == 0.0


def test_sobel_nms_step_edge_detected():
    img = np.full((32, 32), 0.2, np.float32)
    img[:, 16:] = 0.8
    out = np.asarray(sobel_nms(jnp.asarray(img), 0.05, 0.5))
    # a strong vertical edge: strong pixels along a thin column
    cols = np.nonzero((out == 2.0).any(axis=0))[0]
    assert len(cols) >= 1
    assert all(14 <= c <= 17 for c in cols)
    # thinned: at most 2 columns survive NMS
    assert len(cols) <= 2


# --- avgpool ref --------------------------------------------------------


@pytest.mark.parametrize("factor", [1, 2, 3, 4])
def test_avgpool_ref_mean_preserved(factor):
    img = _img(24, 24, 5)
    out = ref.avgpool_ref(img, factor)
    assert out.shape == (24 // factor, 24 // factor)
    assert abs(float(out.mean()) - float(img.mean())) < 1e-6
