"""Synthetic scene generator — Python twin of `rust/src/dataset/scene.rs`.

Used at build time for kernel calibration and python tests. The Rust
generator is the production one; the two are statistical equivalents
(same object model, radius law, contrast ranges), not bit-identical.

Scene model (DESIGN.md §3): grayscale 384x384, background 0.5 with smooth
low-frequency variation plus white noise; N objects rendered as rotated
anisotropic Gaussian bumps, bright (class 0) or dark (class 1). Crowded
scenes force smaller radii — the natural mechanism by which low-capacity
detectors lose accuracy on high object counts, mirroring the paper's
Figure 2 phenomenon.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

NATIVE_RES = 384
NOISE_STD = 0.02
BG_WAVE_AMP = 0.02
CONTRAST_RANGE = (0.20, 0.60)
MAX_PLACE_TRIES = 40


@dataclass
class SceneObject:
    cx: float
    cy: float
    rx: float  # half-width of the ground-truth box
    ry: float  # half-height
    cls: int  # 0 bright, 1 dark
    contrast: float
    theta: float

    @property
    def box(self) -> tuple[float, float, float, float]:
        return (
            self.cx - self.rx,
            self.cy - self.ry,
            self.cx + self.rx,
            self.cy + self.ry,
        )


def radius_range(n: int) -> tuple[float, float]:
    """Radius law: more objects -> smaller objects (crowding).

    Calibrated (compile/calibrate.py) so the low-capacity detectors keep
    up on sparse scenes but miss a growing fraction of crowded-scene
    objects — the paper's Figure 2 phenomenon.
    """
    if n <= 1:
        return 16.0, 32.0
    hi = 32.0 / (1.0 + 0.35 * (n - 1))
    hi = max(hi, 8.0)
    return max(5.0, hi / 2.5), hi


def _boxes_overlap(a, b, slack: float = 4.0) -> bool:
    return not (
        a[2] + slack < b[0]
        or b[2] + slack < a[0]
        or a[3] + slack < b[1]
        or b[3] + slack < a[1]
    )


def place_objects(n: int, rng: np.random.Generator) -> list[SceneObject]:
    lo, hi = radius_range(n)
    objs: list[SceneObject] = []
    for _ in range(n):
        for _try in range(MAX_PLACE_TRIES):
            r = float(rng.uniform(lo, hi))
            aspect = float(rng.uniform(0.75, 1.33))
            rx, ry = r * aspect, r / aspect
            margin = max(rx, ry) + 4.0
            cx = float(rng.uniform(margin, NATIVE_RES - margin))
            cy = float(rng.uniform(margin, NATIVE_RES - margin))
            cand = SceneObject(
                cx,
                cy,
                rx,
                ry,
                cls=int(rng.integers(0, 2)),
                contrast=float(rng.uniform(*CONTRAST_RANGE)),
                theta=float(rng.uniform(0, math.pi)),
            )
            if all(not _boxes_overlap(cand.box, o.box) for o in objs):
                objs.append(cand)
                break
        # if placement failed after MAX_PLACE_TRIES the object is dropped;
        # ground truth is whatever was actually rendered.
    return objs


def render(objs: list[SceneObject], rng: np.random.Generator) -> np.ndarray:
    n = NATIVE_RES
    yy, xx = np.mgrid[0:n, 0:n].astype(np.float32)
    # smooth background
    fx = float(rng.uniform(0.5, 2.0))
    fy = float(rng.uniform(0.5, 2.0))
    ph = float(rng.uniform(0, 2 * math.pi))
    img = 0.5 + BG_WAVE_AMP * np.sin(
        2 * math.pi * (fx * xx / n + fy * yy / n) + ph
    ).astype(np.float32)
    for o in objs:
        # rotated anisotropic Gaussian bump; std = half-extent / 2 so the
        # visible edge sits near the GT box boundary.
        ct, st = math.cos(o.theta), math.sin(o.theta)
        dx, dy = xx - o.cx, yy - o.cy
        u = ct * dx + st * dy
        v = -st * dx + ct * dy
        sx, sy = o.rx / 2.0, o.ry / 2.0
        bump = np.exp(-0.5 * ((u / sx) ** 2 + (v / sy) ** 2)).astype(
            np.float32
        )
        sign = 1.0 if o.cls == 0 else -1.0
        img += sign * o.contrast * bump
    img += rng.normal(0.0, NOISE_STD, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_scene(
    n_objects: int, seed: int
) -> tuple[np.ndarray, list[SceneObject]]:
    rng = np.random.default_rng(seed)
    objs = place_objects(n_objects, rng)
    return render(objs, rng), objs
