"""AOT compile path: lower every ECORE compute graph to HLO text.

Run once via `make artifacts`; the Rust coordinator loads the artifacts
through the PJRT C API and Python never appears on the request path.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Besides the `.hlo.txt` files this writes `manifest.json`, the contract
between the build path and the Rust runtime: artifact shapes, decode
parameters (thresholds, per-band box radii), and analytic FLOP counts
for the device simulator.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, in_shape):
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    return jax.jit(fn).lower(spec)


def _detector_entry(name: str, artifact_name: str | None = None) -> dict:
    v = M.VARIANTS[name]
    return {
        "kind": "detector",
        "file": f"{artifact_name or name}.hlo.txt",
        "input": {"shape": [M.NATIVE_RES, M.NATIVE_RES], "dtype": "f32"},
        "output": {
            "shape": [2, v.k, v.res, v.res],
            "dtype": "f32",
        },
        "params": {
            "res": v.res,
            "factor": v.factor,
            "k": v.k,
            "sigmas": M.pyramid_sigmas(v),
            "band_radii_native": M.band_radii_native(v),
            "threshold": v.threshold,
        },
        "flops": M.detector_flops(name),
    }


def build_manifest() -> dict:
    models = {}
    for name in M.VARIANTS:
        models[name] = _detector_entry(name)
    for alias, base in M.GATEWAY_MODELS.items():
        models[alias] = _detector_entry(base, artifact_name=alias)
        models[alias]["kind"] = "gateway_detector"
    models["canny"] = {
        "kind": "canny",
        "file": "canny.hlo.txt",
        "input": {"shape": [M.NATIVE_RES, M.NATIVE_RES], "dtype": "f32"},
        "output": {"shape": [M.CANNY_RES, M.CANNY_RES], "dtype": "f32"},
        "params": {
            "res": M.CANNY_RES,
            "factor": M.NATIVE_RES // M.CANNY_RES,
            "sigma": M.CANNY_SIGMA,
            "lo": M.CANNY_LO,
            "hi": M.CANNY_HI,
        },
        "flops": M.canny_flops(),
    }
    return {
        "version": MANIFEST_VERSION,
        "native_res": M.NATIVE_RES,
        "models": models,
    }


def _inputs_fingerprint() -> str:
    """Hash of every compile-path source file; lets `make` skip rebuilds."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", help="subset of artifact names to rebuild"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = build_manifest()
    manifest["fingerprint"] = _inputs_fingerprint()

    jobs: list[tuple[str, object]] = []
    for name in M.VARIANTS:
        jobs.append((name, M.make_detector(name)))
    for alias, base in M.GATEWAY_MODELS.items():
        jobs.append((alias, M.make_detector(base)))
    jobs.append(("canny", M.make_canny()))

    for name, fn in jobs:
        if args.only and name not in args.only:
            continue
        path = os.path.join(args.out_dir, manifest["models"][name]["file"])
        text = to_hlo_text(lower_fn(fn, (M.NATIVE_RES, M.NATIVE_RES)))
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
