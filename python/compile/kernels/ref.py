"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an exact reference here, written
with plain jax.numpy ops and no Pallas machinery. The pytest suite asserts
allclose between kernel and oracle over hypothesis-driven shape/parameter
sweeps — this is the core correctness signal for Layer 1.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

__all__ = [
    "gaussian_taps",
    "blur2d_ref",
    "dog_localmax_ref",
    "sobel_nms_ref",
    "avgpool_ref",
]


def gaussian_taps(sigma: float, max_radius: int = 64) -> np.ndarray:
    """Normalized 1-D Gaussian taps, truncated at 2.5*sigma (capped).

    The cap bounds HLO size for the largest pyramid scales; both the Pallas
    kernel and this oracle share the same taps so truncation is consistent.
    """
    radius = min(int(math.ceil(2.5 * float(sigma))), max_radius)
    radius = max(radius, 1)
    xs = np.arange(-radius, radius + 1, dtype=np.float64)
    t = np.exp(-0.5 * (xs / float(sigma)) ** 2)
    t /= t.sum()
    return t.astype(np.float32)


def _pad_edge(x: jnp.ndarray, radius: int, axis: int) -> jnp.ndarray:
    pad = [(0, 0), (0, 0)]
    pad[axis] = (radius, radius)
    return jnp.pad(x, pad, mode="edge")


def _conv1d_ref(x: jnp.ndarray, taps: np.ndarray, axis: int) -> jnp.ndarray:
    radius = (len(taps) - 1) // 2
    padded = _pad_edge(x, radius, axis)
    out = jnp.zeros_like(x)
    n = x.shape[axis]
    for i, w in enumerate(taps):
        if axis == 0:
            sl = padded[i : i + n, :]
        else:
            sl = padded[:, i : i + n]
        out = out + jnp.float32(w) * sl
    return out


def blur2d_ref(img: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """Separable Gaussian blur with edge padding. img: [H, W] f32."""
    taps = gaussian_taps(sigma)
    return _conv1d_ref(_conv1d_ref(img, taps, axis=1), taps, axis=0)


def _maxpool3_ref(r: jnp.ndarray) -> jnp.ndarray:
    """3x3 max pool, edge padded (so border peaks survive)."""
    p = jnp.pad(r, ((1, 1), (1, 1)), mode="edge")
    h, w = r.shape
    m = r
    for dy in range(3):
        for dx in range(3):
            m = jnp.maximum(m, p[dy : dy + h, dx : dx + w])
    return m


def dog_localmax_ref(pyr: jnp.ndarray) -> jnp.ndarray:
    """Difference-of-Gaussians + per-scale 3x3 local-max heat map.

    pyr: [K+1, H, W] Gaussian pyramid (increasing sigma).
    Returns heat: [2, K, H, W] where channel 0 = bright-blob responses,
    channel 1 = dark-blob responses; a pixel is nonzero iff it is the
    3x3 local maximum of its (class, scale) response map.
    """
    k1, h, w = pyr.shape
    k = k1 - 1
    out = []
    for cls in range(2):
        maps = []
        for s in range(k):
            d = pyr[s] - pyr[s + 1]
            r = jnp.maximum(d if cls == 0 else -d, 0.0)
            m = _maxpool3_ref(r)
            maps.append(jnp.where(r >= m, r, 0.0))
        out.append(jnp.stack(maps))
    return jnp.stack(out)


def sobel_nms_ref(img: jnp.ndarray, lo: float, hi: float) -> jnp.ndarray:
    """Canny core: Sobel gradient -> direction-quantized NMS -> double
    threshold. Returns [H, W] f32 with values 0 (none), 1 (weak), 2 (strong).

    Hysteresis (weak-to-strong linking) is a graph traversal and lives in
    the Rust estimator; this kernel produces its input.
    """
    h, w = img.shape
    p = jnp.pad(img, ((1, 1), (1, 1)), mode="edge")

    def sh(dy, dx):
        return p[1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]

    gx = (
        (sh(-1, 1) + 2.0 * sh(0, 1) + sh(1, 1))
        - (sh(-1, -1) + 2.0 * sh(0, -1) + sh(1, -1))
    )
    gy = (
        (sh(1, -1) + 2.0 * sh(1, 0) + sh(1, 1))
        - (sh(-1, -1) + 2.0 * sh(-1, 0) + sh(-1, 1))
    )
    mag = jnp.sqrt(gx * gx + gy * gy)

    # Quantize direction into {0: E-W, 1: +45deg, 2: N-S, 3: -45deg} using
    # tan(22.5)/tan(67.5) comparisons on |gy| vs |gx| without division.
    ax, ay = jnp.abs(gx), jnp.abs(gy)
    t1 = jnp.float32(0.41421356)  # tan(22.5 deg)
    t2 = jnp.float32(2.41421356)  # tan(67.5 deg)
    same_sign = (gx * gy) >= 0
    d0 = ay <= t1 * ax
    d2 = ay > t2 * ax
    diag = (~d0) & (~d2)
    d1 = diag & same_sign
    d3 = diag & (~same_sign)

    mp = jnp.pad(mag, ((1, 1), (1, 1)), mode="constant")

    def msh(dy, dx):
        return mp[1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]

    keep = (
        (d0 & (mag >= msh(0, 1)) & (mag >= msh(0, -1)))
        | (d2 & (mag >= msh(1, 0)) & (mag >= msh(-1, 0)))
        | (d1 & (mag >= msh(1, 1)) & (mag >= msh(-1, -1)))
        | (d3 & (mag >= msh(1, -1)) & (mag >= msh(-1, 1)))
    )
    thinned = jnp.where(keep, mag, 0.0)
    return jnp.where(
        thinned >= hi, 2.0, jnp.where(thinned >= lo, 1.0, 0.0)
    ).astype(jnp.float32)


def avgpool_ref(img: jnp.ndarray, factor: int) -> jnp.ndarray:
    """Non-overlapping average pool by integer factor. img: [H, W]."""
    if factor == 1:
        return img
    h, w = img.shape
    assert h % factor == 0 and w % factor == 0, (h, w, factor)
    return img.reshape(h // factor, factor, w // factor, factor).mean(
        axis=(1, 3)
    )
