"""L1 Pallas kernel: Canny core — Sobel gradients, direction-quantized
non-maximum suppression, double threshold.

The gateway's Edge-Detection (ED) estimator runs this on every incoming
image; the Rust side finishes the Canny pipeline (hysteresis linking +
connected-component contour counting), which is graph traversal and does
not belong in a data-parallel kernel.

The whole image is processed as a single block: the ED input is 192x192
f32 (144 KiB) after the L2 average-pool, far below any VMEM budget, and
the NMS stencil would otherwise need 2-pixel halos on both axes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sobel_nms"]


def _sobel_kernel(x_ref, o_ref, *, lo, hi):
    img = x_ref[...]
    h, w = img.shape
    p = jnp.pad(img, ((1, 1), (1, 1)), mode="edge")

    def sh(dy, dx):
        return p[1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]

    gx = (
        (sh(-1, 1) + 2.0 * sh(0, 1) + sh(1, 1))
        - (sh(-1, -1) + 2.0 * sh(0, -1) + sh(1, -1))
    )
    gy = (
        (sh(1, -1) + 2.0 * sh(1, 0) + sh(1, 1))
        - (sh(-1, -1) + 2.0 * sh(-1, 0) + sh(-1, 1))
    )
    mag = jnp.sqrt(gx * gx + gy * gy)

    ax, ay = jnp.abs(gx), jnp.abs(gy)
    t1 = jnp.float32(0.41421356)  # tan(22.5 deg)
    t2 = jnp.float32(2.41421356)  # tan(67.5 deg)
    same_sign = (gx * gy) >= 0
    d0 = ay <= t1 * ax
    d2 = ay > t2 * ax
    diag = (~d0) & (~d2)
    d1 = diag & same_sign
    d3 = diag & (~same_sign)

    mp = jnp.pad(mag, ((1, 1), (1, 1)), mode="constant")

    def msh(dy, dx):
        return mp[1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]

    keep = (
        (d0 & (mag >= msh(0, 1)) & (mag >= msh(0, -1)))
        | (d2 & (mag >= msh(1, 0)) & (mag >= msh(-1, 0)))
        | (d1 & (mag >= msh(1, 1)) & (mag >= msh(-1, -1)))
        | (d3 & (mag >= msh(1, -1)) & (mag >= msh(-1, 1)))
    )
    thinned = jnp.where(keep, mag, 0.0)
    o_ref[...] = jnp.where(
        thinned >= jnp.float32(hi),
        2.0,
        jnp.where(thinned >= jnp.float32(lo), 1.0, 0.0),
    ).astype(jnp.float32)


def sobel_nms(img: jnp.ndarray, lo: float, hi: float) -> jnp.ndarray:
    """img: [H, W] f32 -> edge classes [H, W] f32 in {0, 1, 2}.

    Matches `ref.sobel_nms_ref` exactly.
    """
    h, w = img.shape
    kernel = functools.partial(_sobel_kernel, lo=float(lo), hi=float(hi))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(img)
