"""L1 Pallas kernel: separable Gaussian blur.

Two row-/column-tiled 1-D convolution passes. Tiling rationale
(DESIGN.md §Hardware-Adaptation): this is a stencil (VPU) workload, so
blocks keep the 128-lane minor dimension whole and tile the major
dimension. The horizontal pass tiles rows (each block holds full rows, so
the conv along W needs no halo exchange); the vertical pass tiles columns
(full columns per block). Edge padding happens inside the kernel body on
the VMEM-resident block.

All kernels lower with interpret=True: on this CPU-PJRT testbed the
interpreter traces the body to plain HLO so the compiled artifact runs
natively; real-TPU Mosaic lowering is a compile-only target (DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gaussian_taps

__all__ = ["blur1d", "blur2d"]

# Major-dimension tile for the 1-D conv passes. 64 rows x 384 cols f32
# = 96 KiB per block plus the padded copy — comfortably inside a 16 MiB
# VMEM budget with double buffering.
_TILE = 64


def _conv_rows_kernel(x_ref, o_ref, *, taps):
    """Convolve along the last axis of a [tile, W] block."""
    x = x_ref[...]
    radius = (len(taps) - 1) // 2
    w = x.shape[1]
    padded = jnp.pad(x, ((0, 0), (radius, radius)), mode="edge")
    acc = jnp.zeros_like(x)
    for i, t in enumerate(taps):
        acc = acc + jnp.float32(t) * padded[:, i : i + w]
    o_ref[...] = acc


def _conv_cols_kernel(x_ref, o_ref, *, taps):
    """Convolve along the first axis of a [H, tile] block."""
    x = x_ref[...]
    radius = (len(taps) - 1) // 2
    h = x.shape[0]
    padded = jnp.pad(x, ((radius, radius), (0, 0)), mode="edge")
    acc = jnp.zeros_like(x)
    for i, t in enumerate(taps):
        acc = acc + jnp.float32(t) * padded[i : i + h, :]
    o_ref[...] = acc


def _tile(n: int) -> int:
    """Largest tile <= _TILE that divides n (grid must tile exactly)."""
    for cand in range(min(_TILE, n), 0, -1):
        if n % cand == 0:
            return cand
    return 1


def blur1d(img: jnp.ndarray, sigma: float, axis: int) -> jnp.ndarray:
    """One 1-D Gaussian pass along `axis` of an [H, W] f32 image."""
    taps = tuple(float(t) for t in gaussian_taps(sigma))
    h, w = img.shape
    if axis == 1:
        th = _tile(h)
        kernel = functools.partial(_conv_rows_kernel, taps=taps)
        return pl.pallas_call(
            kernel,
            grid=(h // th,),
            in_specs=[pl.BlockSpec((th, w), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((th, w), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
            interpret=True,
        )(img)
    tw = _tile(w)
    kernel = functools.partial(_conv_cols_kernel, taps=taps)
    return pl.pallas_call(
        kernel,
        grid=(w // tw,),
        in_specs=[pl.BlockSpec((h, tw), lambda i: (0, i))],
        out_specs=pl.BlockSpec((h, tw), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(img)


def blur2d(img: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """Separable Gaussian blur of an [H, W] f32 image (edge padded).

    Matches `ref.blur2d_ref` exactly (same truncated taps).
    """
    return blur1d(blur1d(img, sigma, axis=1), sigma, axis=0)
