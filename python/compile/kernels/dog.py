"""L1 Pallas kernel: fused Difference-of-Gaussians + local-max heat map.

Given the Gaussian pyramid [K+1, H, W], one grid step per scale computes
the DoG band, splits it into bright (+) / dark (-) blob responses, and
zeroes every pixel that is not the 3x3 local maximum of its response map
— producing the sparse peak heat map the Rust decoder consumes.

Two input refs alias the pyramid at consecutive scale indices (block
shape [1, H, W], index maps k and k+1) so each grid step streams exactly
the two scale planes it needs — the whole pyramid never has to sit in
VMEM at once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dog_localmax"]


def _maxpool3(r: jnp.ndarray) -> jnp.ndarray:
    p = jnp.pad(r, ((1, 1), (1, 1)), mode="edge")
    h, w = r.shape
    m = r
    for dy in range(3):
        for dx in range(3):
            m = jnp.maximum(m, p[dy : dy + h, dx : dx + w])
    return m


def _dog_kernel(lo_ref, hi_ref, o_ref):
    d = lo_ref[0] - hi_ref[0]
    for cls in range(2):
        r = jnp.maximum(d if cls == 0 else -d, 0.0)
        m = _maxpool3(r)
        o_ref[cls, 0] = jnp.where(r >= m, r, 0.0)


def dog_localmax(pyr: jnp.ndarray) -> jnp.ndarray:
    """pyr: [K+1, H, W] f32 -> heat [2, K, H, W] f32.

    Matches `ref.dog_localmax_ref` exactly.
    """
    k1, h, w = pyr.shape
    k = k1 - 1
    return pl.pallas_call(
        _dog_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, h, w), lambda s: (s + 1, 0, 0)),
        ],
        out_specs=pl.BlockSpec((2, 1, h, w), lambda s: (0, s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, k, h, w), jnp.float32),
        interpret=True,
    )(pyr, pyr)
