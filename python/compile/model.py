"""L2: the ECORE detector-family compute graphs (build-time JAX).

The paper's eight object-detection models (SSD v1/Lite, EfficientDet-Lite
0/1/2, YOLOv8 n/s/m) are substituted by a parametric multi-scale DoG blob
detector family (DESIGN.md §3): each variant takes the native 384x384
image, average-pools to its working resolution, builds an incremental
Gaussian pyramid (L1 `blur2d` kernels), and emits the fused
DoG + local-max heat map (L1 `dog_localmax`). Capacity ordering — working
resolution and scale count — reproduces the paper's accuracy/complexity
trade-off with *real* inference per request.

Every function here is lowered once by `aot.py` to an HLO-text artifact;
Python never runs on the request path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.blur import blur2d
from .kernels.dog import dog_localmax
from .kernels.sobel import sobel_nms

__all__ = [
    "NATIVE_RES",
    "Variant",
    "VARIANTS",
    "GATEWAY_MODELS",
    "pyramid_sigmas",
    "band_radii_native",
    "incremental_sigmas",
    "make_pyramid",
    "make_detector",
    "make_canny",
    "detector_flops",
    "canny_flops",
]

# Native request resolution: every camera frame enters the system as a
# [384, 384] f32 grayscale tensor. 384 is divisible by all working
# resolutions (96, 128, 192, 384) so downsampling is an exact average pool.
NATIVE_RES = 384

# Canny (ED estimator) parameters — shared with the Rust gateway via the
# artifact manifest. 96x96 keeps the ED estimator ~4x cheaper than the
# SSD front-end (the paper's overhead ordering: ED < SF), at the price of
# coarse counts on crowded scenes — exactly the paper's characterization.
CANNY_RES = 96
CANNY_SIGMA = 1.0
CANNY_LO = 0.05
CANNY_HI = 0.12


@dataclass(frozen=True)
class Variant:
    """One detector variant (stands in for one paper model)."""

    name: str
    res: int  # working resolution (divides NATIVE_RES)
    k: int  # number of DoG bands (pyramid has k+1 levels)
    sigma0: float  # finest pyramid sigma, in working-res pixels
    sigma_max: float  # coarsest pyramid sigma (sets the ratio)
    threshold: float  # peak response decode threshold (Rust side)

    @property
    def factor(self) -> int:
        return NATIVE_RES // self.res

    @property
    def ratio(self) -> float:
        return (self.sigma_max / self.sigma0) ** (1.0 / self.k)


def _v(name, res, k, sigma0, sigma_max, threshold=0.030) -> Variant:
    return Variant(name, res, k, sigma0, sigma_max, threshold)


# The eight backend models, ordered by capacity. sigma_max is chosen so
# the coarsest band covers ~30 native-res pixels of blob radius at every
# working resolution (sigma_max * factor ~= 30); see DESIGN.md §3.
VARIANTS: dict[str, Variant] = {
    v.name: v
    for v in [
        _v("ssd_v1", 96, 3, 1.4, 7.5),
        _v("ssd_lite", 96, 4, 1.2, 7.5),
        _v("effdet_lite0", 128, 4, 1.3, 10.0),
        _v("effdet_lite1", 128, 5, 1.2, 10.0),
        _v("effdet_lite2", 192, 5, 1.3, 15.0),
        _v("yolov8n", 192, 6, 1.2, 15.0),
        _v("yolov8s", 384, 6, 1.6, 30.0),
        _v("yolov8m", 384, 7, 1.4, 30.0),
        # yolov8x generates pseudo-ground-truth for the video dataset
        # (paper §4.1.1); it is not a routing target.
        _v("yolov8x", 384, 8, 1.3, 30.0, threshold=0.028),
    ]
}

# Models that run *on the gateway*: the SSD-based front-end estimator (SF)
# is the cheapest backend variant re-exported under its own artifact name.
GATEWAY_MODELS = {"ssd_front": "ssd_v1"}


def pyramid_sigmas(v: Variant) -> list[float]:
    """Absolute sigmas of the k+1 pyramid levels (geometric ladder)."""
    return [v.sigma0 * v.ratio**i for i in range(v.k + 1)]


def band_radii_native(v: Variant) -> list[float]:
    """Expected blob radius (native-res px) for each DoG band.

    Band k sits between pyramid levels k and k+1, so its characteristic
    sigma is their geometric mean; empirical calibration against planted
    Gaussian bumps (python -m compile.calibrate) gives box half-extent
    ~= 2.0 x that sigma (native px). The Rust decoder turns peak
    (band, y, x) into a box with this radius.
    """
    s = pyramid_sigmas(v)
    return [
        2.0 * math.sqrt(s[i] * s[i + 1]) * v.factor for i in range(v.k)
    ]


def _avgpool(img: jnp.ndarray, factor: int) -> jnp.ndarray:
    if factor == 1:
        return img
    h, w = img.shape
    return img.reshape(h // factor, factor, w // factor, factor).mean(
        axis=(1, 3)
    )


def incremental_sigmas(v: Variant) -> list[float]:
    """Per-level *incremental* blur sigmas.

    Level 0 blurs the raw image with sigma_0; level i+1 blurs level i with
    sqrt(sigma_{i+1}^2 - sigma_i^2). Incremental blurring keeps every
    conv's taps short — the perf-critical choice recorded in DESIGN.md
    §Perf (absolute blurs at sigma ~30 would need ~150-tap convs).
    """
    s = pyramid_sigmas(v)
    out = [s[0]]
    for i in range(v.k):
        out.append(math.sqrt(s[i + 1] ** 2 - s[i] ** 2))
    return out


def make_pyramid(img: jnp.ndarray, v: Variant) -> jnp.ndarray:
    """[res, res] f32 -> Gaussian pyramid [k+1, res, res] via L1 blurs."""
    inc = incremental_sigmas(v)
    levels = [blur2d(img, inc[0])]
    for d in inc[1:]:
        levels.append(blur2d(levels[-1], d))
    return jnp.stack(levels)


def make_detector(name: str):
    """Build the full detector graph for one variant.

    Returns fn: [NATIVE_RES, NATIVE_RES] f32 -> (heat [2, k, res, res],)
    The 1-tuple return matches the `return_tuple=True` lowering contract
    the Rust loader unwraps with `to_tuple1()`.
    """
    v = VARIANTS[name]

    def fn(img: jnp.ndarray):
        x = _avgpool(img, v.factor)
        pyr = make_pyramid(x, v)
        return (dog_localmax(pyr),)

    return fn


def make_canny():
    """Gateway ED-estimator graph.

    [NATIVE_RES, NATIVE_RES] f32 -> (edge classes [CANNY_RES, CANNY_RES],)
    with values {0: none, 1: weak, 2: strong}; hysteresis + contour
    counting happen in the Rust estimator.
    """

    def fn(img: jnp.ndarray):
        x = _avgpool(img, NATIVE_RES // CANNY_RES)
        x = blur2d(x, CANNY_SIGMA)
        return (sobel_nms(x, CANNY_LO, CANNY_HI),)

    return fn


# ---------------------------------------------------------------------------
# Analytic FLOP counts — consumed by the Rust device simulator, which maps
# FLOPs through per-device throughput/power models to latency and energy.
# ---------------------------------------------------------------------------


def _taps_len(sigma: float, max_radius: int = 64) -> int:
    radius = max(min(int(math.ceil(2.5 * sigma)), max_radius), 1)
    return 2 * radius + 1


def detector_flops(name: str) -> int:
    """Total FLOPs for one forward pass of a detector variant."""
    v = VARIANTS[name]
    n = NATIVE_RES
    flops = n * n  # average pool (~1 add/px)
    px = v.res * v.res
    for d in incremental_sigmas(v):
        # separable blur: 2 passes x (mul+add per tap)
        flops += px * 2 * 2 * _taps_len(d)
    # DoG + relu + 3x3 maxpool + select, both classes, k bands
    flops += v.k * px * 2 * (1 + 1 + 9 + 1)
    return flops


def canny_flops() -> int:
    n, r = NATIVE_RES, CANNY_RES
    px = r * r
    flops = n * n
    flops += px * 2 * 2 * _taps_len(CANNY_SIGMA)
    flops += px * 40  # sobel (2x 3x3), magnitude, quantize, nms, threshold
    return flops
