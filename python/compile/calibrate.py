"""Calibration harness: run detector variants over synthetic scenes and
report per-variant detection quality, to tune decode thresholds and the
band-radius law before they are frozen into the artifact manifest.

Usage: python -m compile.calibrate [--variants ssd_v1 yolov8m] [--scenes 8]

This is a build-time tool (not part of the serving system); its decoder
mirrors `rust/src/detection/decode.rs`.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from . import model as M
from . import scenegen


def decode(heat: np.ndarray, v: M.Variant, thr: float | None = None):
    """Peak heat map -> boxes; mirror of the Rust decoder."""
    thr = v.threshold if thr is None else thr
    radii = M.band_radii_native(v)
    f = v.factor
    cls_idx, band_idx, ys, xs = np.nonzero(heat > thr)
    dets = []
    for c, b, y, x in zip(cls_idx, band_idx, ys, xs):
        score = float(heat[c, b, y, x])
        r = radii[b]
        cx, cy = (x + 0.5) * f, (y + 0.5) * f
        dets.append((cx - r, cy - r, cx + r, cy + r, score, int(c)))
    # greedy center-distance NMS across bands AND classes: a blob responds
    # in 2-3 adjacent bands and casts an opposite-class "ring"; both fall
    # within the winning box's radius, while true neighbours are separated
    # by at least the sum of radii (scene placement slack).
    dets.sort(key=lambda d: -d[4])
    kept = []
    for d in dets:
        cx, cy = (d[0] + d[2]) / 2, (d[1] + d[3]) / 2
        rr = (d[2] - d[0]) / 2
        ok = True
        for k in kept:
            kx, ky = (k[0] + k[2]) / 2, (k[1] + k[3]) / 2
            kr = (k[2] - k[0]) / 2
            lim = 0.9 * max(rr, kr)
            if (cx - kx) ** 2 + (cy - ky) ** 2 < lim * lim:
                ok = False
                break
        if ok:
            kept.append(d)
    return kept


def _iou(a, b) -> float:
    ix = max(0.0, min(a[2], b[2]) - max(a[0], b[0]))
    iy = max(0.0, min(a[3], b[3]) - max(a[1], b[1]))
    inter = ix * iy
    ua = (a[2] - a[0]) * (a[3] - a[1]) + (b[2] - b[0]) * (b[3] - b[1]) - inter
    return inter / ua if ua > 0 else 0.0


def match_stats(dets, objs, iou_thr=0.5):
    matched = set()
    tp = 0
    for d in dets:
        best, bi = 0.0, -1
        for i, o in enumerate(objs):
            if i in matched or o.cls != d[5]:
                continue
            g = o.box + (0.0, o.cls)
            v = _iou(d, (g[0], g[1], g[2], g[3], 0, o.cls))
            if v > best:
                best, bi = v, i
        if best >= iou_thr:
            matched.add(bi)
            tp += 1
    fp = len(dets) - tp
    fn = len(objs) - tp
    return tp, fp, fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variants", nargs="*", default=list(M.VARIANTS))
    ap.add_argument("--scenes", type=int, default=6)
    ap.add_argument("--thr", type=float, default=None)
    args = ap.parse_args()

    workloads = {"sparse(n=1)": 1, "medium(n=3)": 3, "crowded(n=7)": 7}
    for name in args.variants:
        v = M.VARIANTS[name]
        fn = jax.jit(M.make_detector(name))
        rows = []
        for wname, n in workloads.items():
            agg = np.zeros(3, dtype=int)
            for s in range(args.scenes):
                img, objs = scenegen.make_scene(n, seed=1000 * n + s)
                heat = np.asarray(fn(img)[0])
                dets = decode(heat, v, args.thr)
                agg += np.array(match_stats(dets, objs))
            tp, fp, fn_ = agg
            prec = tp / max(tp + fp, 1)
            rec = tp / max(tp + fn_, 1)
            rows.append(f"{wname}: P={prec:.2f} R={rec:.2f} tp={tp} fp={fp} fn={fn_}")
        print(f"{name:14s} " + " | ".join(rows))


if __name__ == "__main__":
    main()
